//! The T3-fused ring all-gather engine (§7.1 "Other collectives").
//!
//! The paper's track-and-trigger mechanism fuses the reduce-scatter half
//! of an all-reduce into the producer GEMM; the all-gather half can
//! likewise be overlapped with its neighbors instead of running as a
//! serialized CU kernel. This module models that AG as a *per-rank
//! state machine* ([`AllGatherRank`]), mirroring
//! [`super::fused::FusedRank`] / [`super::collective_run::RingRank`]:
//!
//! * **Trigger**: the rank's first send is its own fully-reduced chunk,
//!   launched the moment the fused RS's tracker completes it and the
//!   egress port drains the RS's remaining windows
//!   ([`AgRankSpec::start`], computed by
//!   [`super::fused::FusedResult::ag_trigger`]) — no kernel launch, no
//!   wait for the full calendar drain (whose tail past the trigger is
//!   ingress-side only).
//! * **Cut-through forwarding**: a baseline CU all-gather kernel
//!   store-and-forwards — step `s+1` reads back from DRAM what step `s`
//!   wrote, so every hop pays the full link latency plus a memory
//!   round-trip. The pre-programmed DMA of the fused AG instead forwards
//!   an arriving chunk directly from the ingress path while writing it to
//!   local memory in parallel: the forward's egress window opens at the
//!   incoming window's first-byte arrival, rate-capped by the incoming
//!   feed (a slow upstream hop throttles the forward — the transfer
//!   stays causal per byte). Only the rank's *own* chunk is ever read
//!   from DRAM, which both pipelines the ring (one latency term instead
//!   of `N-1`) and removes `N-2` chunk reads of DRAM traffic.
//! * **Consumer overlap** ([`ConsumerSpec`]): optionally, the next
//!   sub-layer's GEMM runs inside the same rank machine while the AG
//!   drains. The GEMM's stage reads travel the MC *compute* stream, the
//!   AG's ingress stores the *comm* stream, and the configured
//!   [`crate::config::ArbPolicy`] (`hw::mc`) arbitrates between them —
//!   the producer/consumer-fused kernels of Triton-distributed, expressed
//!   through T3's memory-controller machinery. Stage `s` of `S` is gated
//!   on the proportional prefix of gathered chunks having arrived
//!   (fine-grained consumption, not a barrier on the full gather).
//!
//! Two drivers exist, exactly as for the other rank machines:
//! [`run_fused_ag`] is the §5.1.1 loopback mirror (one rank, messages
//! delivered back to itself); [`crate::cluster::run_ag_cluster`] drives
//! `tp` interacting ranks with per-rank trigger times and per-edge links,
//! reproducing the mirror bit-for-bit in its uniform configuration.

use crate::config::{ArbPolicy, GpuConfig, LinkConfig, SystemConfig};
use crate::gemm::traffic::{gemm_bytes_per_flop, gemm_traffic, stage_reads, WriteMode};
use crate::gemm::StagePlan;
use crate::hw::hbm::{GroupId, TrafficClass, Txn, TxnKind};
use crate::hw::mc::{intensity_class, Stream};
use crate::sim::stats::DramCounters;
use crate::sim::time::SimTime;
use crate::trace::{InstantKind, Lane, RankTrace, SinkMode, SpanLabel};

use super::{Ev, GroupTag, Runner, PACE_BATCH};

/// A cross-rank message of the fused all-gather: one hop's chunk arrives
/// at the receiver across `[start, end]` (the sender's egress window
/// shifted by the hop latency). `step` is the ring step the chunk belongs
/// to — identical on both ends (ring steps are globally aligned).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AgMsg {
    /// Globally-aligned ring step the chunk belongs to.
    pub step: u32,
    /// First-byte arrival time at the receiver.
    pub start: SimTime,
    /// Last-byte arrival time at the receiver.
    pub end: SimTime,
}

/// The next sub-layer's GEMM, run inside the AG rank machine so the two
/// contend through the memory-controller arbitration (consumer overlap).
#[derive(Debug, Clone)]
pub struct ConsumerSpec {
    /// The consumer GEMM's stage plan.
    pub plan: StagePlan,
    /// Write mode for the consumer's stores.
    pub write_mode: WriteMode,
    /// Per-rank compute slowdown (1.0 = nominal; the cluster skew model).
    pub compute_scale: f64,
}

/// Construction parameters of one [`AllGatherRank`].
#[derive(Debug, Clone)]
pub struct AgRankSpec {
    /// Total collective payload (all chunks).
    pub bytes: u64,
    /// Ring size.
    pub devices: u64,
    /// When this rank may launch its own chunk's send — its chunk fully
    /// reduced and its egress link free
    /// ([`crate::engine::fused::FusedResult::ag_trigger`]), or the RS
    /// end for serialized compositions.
    pub start: SimTime,
    /// This rank's egress edge (to its downstream ring neighbor).
    pub link: LinkConfig,
    /// MC arbitration policy (matters when a consumer GEMM is present).
    pub policy: ArbPolicy,
    /// The next sub-layer's GEMM to overlap with, if any.
    pub consumer: Option<ConsumerSpec>,
}

/// Result of one fused-AG rank.
#[derive(Debug, Clone, PartialEq)]
pub struct AllGatherResult {
    /// Absolute calendar drain time (AG + consumer GEMM, if any).
    pub total: SimTime,
    /// When the all-gather itself finished on this rank: every send's
    /// egress window closed, every received chunk's stores landed, and
    /// the own-chunk DMA read drained.
    pub ag_done: SimTime,
    /// Per receive-step completion times (stores landed), step order.
    pub step_ends: Vec<SimTime>,
    /// Consumer GEMM retirement (last stage), when a consumer ran.
    pub consumer_done: Option<SimTime>,
    /// DRAM traffic counters for the run.
    pub counters: DramCounters,
    /// Timeline trace (when [`AllGatherRank::enable_trace`] was called).
    pub timeline: Option<RankTrace>,
    /// Total bytes the egress link carried (trace reconciliation).
    pub link_bytes: u64,
}

/// Consumer-GEMM stage machine state (mirrors the producer stage machine
/// of [`super::fused::FusedRank`] / [`super::gemm_run`]).
struct Consumer {
    plan: StagePlan,
    gpu: GpuConfig,
    eff: f64,
    scale: f64,
    write_kind: TxnKind,
    dram_reads: u64,
    stage: u64,
    stage_compute_done: bool,
    /// The current stage is waiting on gathered-chunk arrivals.
    gated: bool,
    done: SimTime,
}

impl Consumer {
    /// Chunks that must be locally available before stage `s` may issue
    /// its reads: the proportional prefix of the gathered activation.
    fn chunks_needed(&self, n: u64, s: u64) -> u64 {
        ((s + 1) * n).div_ceil(self.plan.num_stages).min(n)
    }
}

/// One rank of the fused ring all-gather: an event-driven machine over its
/// own [`Runner`]. Drive with [`AllGatherRank::step`] /
/// [`AllGatherRank::deliver`] like the other rank machines.
pub struct AllGatherRank {
    r: Runner,
    chunk: u64,
    n: u64,
    steps: u32,
    started: bool,
    /// Own-chunk DMA read group drained.
    read_done: bool,
    /// Per send-step egress window closed.
    egress_done: Vec<bool>,
    /// Per receive-step ingress stores landed.
    ingress_done: Vec<bool>,
    ingress_groups: Vec<GroupId>,
    /// Incoming window per receive step (feeds the cut-through forward's
    /// rate cap).
    in_windows: Vec<(SimTime, SimTime)>,
    step_ends: Vec<SimTime>,
    ag_done: SimTime,
    /// Chunks locally available (own chunk + landed receives); gates the
    /// consumer GEMM's stages.
    arrived: u64,
    consumer: Option<Consumer>,
    tags: Vec<(GroupTag, SimTime)>,
}

impl AllGatherRank {
    /// Build one rank's machine from its spec.
    pub fn new(sys: &SystemConfig, spec: &AgRankSpec) -> Self {
        assert!(spec.devices >= 2, "a ring needs at least two ranks");
        let chunk = spec.bytes / spec.devices;
        assert!(chunk > 0, "chunk must be non-empty");
        let steps = (spec.devices - 1) as u32;

        let mut r = Runner::with_link(sys, spec.policy, spec.link.clone());
        let consumer = spec.consumer.as_ref().map(|c| {
            debug_assert!(c.compute_scale >= 1.0);
            let traffic = gemm_traffic(&c.plan, &sys.mem, c.write_mode);
            // MCA threshold class from the consumer's memory intensity
            // (§6.1.3), exactly as the fused producer engine does.
            let machine_balance =
                sys.mem.total_bw_gbps * 1e9 / sys.gpu.sustained_gemm_flops(c.plan.shape.dtype);
            let class = intensity_class(
                gemm_bytes_per_flop(&c.plan, &sys.mem, c.write_mode),
                machine_balance,
            );
            r.mem.set_intensity_class(class);
            Consumer {
                plan: c.plan.clone(),
                gpu: sys.gpu.clone(),
                eff: sys.gpu.gemm_efficiency,
                scale: c.compute_scale,
                write_kind: match c.write_mode {
                    WriteMode::ThroughLlc => TxnKind::Write,
                    WriteMode::BypassLlc => TxnKind::NmcUpdate,
                },
                dram_reads: traffic.dram_reads,
                stage: 0,
                stage_compute_done: false,
                gated: false,
                done: SimTime::MAX,
            }
        });
        // The rank wakes when its reduced chunk is ready.
        r.q.schedule(spec.start, Ev::Marker { step: 0, what: 0 });

        AllGatherRank {
            r,
            chunk,
            n: spec.devices,
            steps,
            started: false,
            read_done: false,
            egress_done: vec![false; steps as usize],
            ingress_done: vec![false; steps as usize],
            ingress_groups: vec![GroupId::NONE; steps as usize],
            in_windows: vec![(SimTime::ZERO, SimTime::ZERO); steps as usize],
            step_ends: vec![SimTime::MAX; steps as usize],
            ag_done: SimTime::MAX,
            arrived: 0,
            consumer,
            tags: Vec::new(),
        }
    }

    /// Record this rank's timeline (`t3::trace`): the AG trigger instant,
    /// link egress/ingress windows, consumer-GEMM stage compute, and DRAM
    /// service lanes. Purely observational.
    pub fn enable_trace(&mut self, rank: u64) {
        self.r.enable_trace(rank);
    }

    /// [`AllGatherRank::enable_trace`] with an explicit [`SinkMode`]
    /// (metrics mode folds spans into per-lane aggregates as they land).
    pub fn enable_trace_with(&mut self, rank: u64, mode: SinkMode) {
        self.r.enable_trace_with(rank, mode);
    }

    /// Rebind this rank's egress (fabric integration). Must be called
    /// before the first event is processed.
    pub fn attach_port(&mut self, port: crate::fabric::EgressPort) {
        debug_assert!(!self.started, "attach_port after the rank started");
        self.r.link_out = port;
    }

    /// Time of this rank's next pending event.
    pub fn next_time(&self) -> Option<SimTime> {
        self.r.q.peek_time()
    }

    fn ag_finished(&self) -> bool {
        self.read_done
            && self.egress_done.iter().all(|&d| d)
            && self.ingress_done.iter().all(|&d| d)
    }

    /// Issue stage `s`'s reads if its gathered prefix has arrived; flag
    /// the consumer as gated otherwise.
    fn try_start_stage(r: &mut Runner, c: &mut Consumer, n: u64, arrived: u64) {
        if arrived < c.chunks_needed(n, c.stage) {
            c.gated = true;
            return;
        }
        c.gated = false;
        let bytes = stage_reads(&c.plan, c.dram_reads, c.stage).max(r.sys.mem.txn_bytes);
        r.submit_tagged(
            bytes,
            TxnKind::Read,
            Stream::Compute,
            TrafficClass::GemmRead,
            GroupTag::StageReads(c.stage),
        );
    }

    /// Reserve the cut-through forward window for send step `fs`: opens at
    /// the incoming window's first-byte arrival, rate-capped by the
    /// incoming feed so no byte is forwarded before it arrived.
    fn forward(&mut self, fs: u32, t: SimTime, out: &mut Vec<AgMsg>) {
        let (in_start, in_end) = self.in_windows[fs as usize - 1];
        let dur = in_end - in_start;
        let w = if dur.is_zero() {
            self.r.egress(t, self.chunk, SpanLabel::Chunk(fs))
        } else {
            let feed_gbps = self.chunk as f64 / dur.as_secs_f64() / 1e9;
            self.r
                .egress_rate_limited(t, self.chunk, feed_gbps, SpanLabel::Chunk(fs))
        };
        self.r.q.schedule(w.done, Ev::EgressDone { pos: fs });
        out.push(AgMsg {
            step: fs,
            start: w.arrive_first,
            end: w.arrive_last,
        });
    }

    /// Process one event; outbound hop messages for the downstream
    /// neighbor are appended to `out`. Returns `false` when the calendar
    /// is empty.
    pub fn step(&mut self, out: &mut Vec<AgMsg>) -> bool {
        let Some((t, ev)) = self.r.next_event() else {
            return false;
        };
        let mut tags = std::mem::take(&mut self.tags);
        self.r.drain_tags(&mut tags);
        for (tag, blocked) in tags.drain(..) {
            match tag {
                GroupTag::DmaReads(0) => self.read_done = true,
                GroupTag::StepIngress(s) => {
                    let si = s as usize;
                    self.ingress_done[si] = true;
                    self.step_ends[si] = t;
                    self.arrived += 1;
                    if let Some(c) = &mut self.consumer {
                        if c.gated {
                            Self::try_start_stage(&mut self.r, c, self.n, self.arrived);
                        }
                    }
                }
                GroupTag::StageReads(s) => {
                    if let Some(c) = &mut self.consumer {
                        if s == c.stage {
                            let ct = c.plan.stage_compute_time(s, &c.gpu, c.gpu.cu_count, c.eff);
                            let ct = if c.scale != 1.0 { ct * c.scale } else { ct };
                            let stall = blocked * c.gpu.stall_unhidden;
                            let lbl = SpanLabel::Stage(s);
                            self.r.sink.span(Lane::CuConsumer, t, t + ct + stall, 0, lbl);
                            self.r.q.schedule_in(ct + stall, Ev::StageCompute(s));
                        }
                    }
                }
                _ => {}
            }
        }
        self.tags = tags;

        match ev {
            Ev::Marker { step: 0, what: 0 } if !self.started => {
                self.started = true;
                self.r.sink.instant(Lane::Tracker, t, InstantKind::AgTrigger);
                // The rank's own reduced chunk joins whatever receives
                // already landed (a late-triggered rank's faster upstream
                // neighbors deliver before its start marker).
                self.arrived += 1;
                // Send the own chunk: DMA reads via the comm stream, the
                // egress window in parallel (pipelined, as in the fused RS).
                self.r.submit_tagged(
                    self.chunk,
                    TxnKind::Read,
                    Stream::Comm,
                    TrafficClass::AgRead,
                    GroupTag::DmaReads(0),
                );
                let w = self.r.egress(t, self.chunk, SpanLabel::Chunk(0));
                self.r.q.schedule(w.done, Ev::EgressDone { pos: 0 });
                out.push(AgMsg {
                    step: 0,
                    start: w.arrive_first,
                    end: w.arrive_last,
                });
                if let Some(c) = &mut self.consumer {
                    Self::try_start_stage(&mut self.r, c, self.n, self.arrived);
                }
            }
            Ev::Marker { step: fs, what: 1 } => self.forward(fs, t, out),
            Ev::EgressDone { pos } => self.egress_done[pos as usize] = true,
            Ev::Ingress { pos, n: cnt } => {
                let txn = Txn {
                    kind: TxnKind::Write,
                    stream: Stream::Comm,
                    class: TrafficClass::AgWrite,
                    group: self.ingress_groups[pos as usize],
                };
                self.r.mem.submit_burst(cnt as u64, txn, &mut self.r.q);
            }
            Ev::StageCompute(s) => {
                if let Some(c) = &mut self.consumer {
                    if s == c.stage {
                        c.stage_compute_done = true;
                    }
                }
            }
            _ => {}
        }

        // Consumer stage retirement (mirrors gemm_run's state machine).
        if let Some(c) = &mut self.consumer {
            if c.stage_compute_done {
                let bytes = c.plan.wgs_in_stage(c.stage) * c.plan.wg_out_bytes();
                self.r
                    .submit_untagged(bytes, c.write_kind, Stream::Compute, TrafficClass::GemmWrite);
                c.stage += 1;
                c.stage_compute_done = false;
                if c.stage < c.plan.num_stages {
                    Self::try_start_stage(&mut self.r, c, self.n, self.arrived);
                } else {
                    c.done = t;
                }
            }
        }

        if self.ag_done == SimTime::MAX && self.ag_finished() {
            self.ag_done = t;
        }
        true
    }

    /// Apply the upstream neighbor's hop-arrival message: pace the chunk's
    /// stores across the window and, when the chunk must travel further,
    /// open the cut-through forward at its first-byte arrival.
    pub fn deliver(&mut self, msg: &AgMsg) {
        let s = msg.step as usize;
        if s >= self.steps as usize || self.ingress_groups[s] != GroupId::NONE {
            return;
        }
        let txns = self.r.mem.txns_for(self.chunk);
        self.ingress_groups[s] = self.r.register_group(txns, GroupTag::StepIngress(msg.step));
        self.in_windows[s] = (msg.start, msg.end);
        self.r
            .sink
            .span(Lane::LinkIngress, msg.start, msg.end, self.chunk, SpanLabel::Chunk(msg.step));
        self.r
            .schedule_ingress_window(msg.step, txns, msg.start, msg.end, PACE_BATCH);
        if msg.step + 1 < self.steps {
            self.r.q.schedule(
                msg.start,
                Ev::Marker {
                    step: msg.step + 1,
                    what: 1,
                },
            );
        }
    }

    /// Consume the drained rank into its result.
    pub fn into_result(mut self) -> AllGatherResult {
        debug_assert!(self.r.mem.idle());
        debug_assert!(self.ag_done != SimTime::MAX, "all-gather did not finish");
        let total = self.r.now();
        // Accounted timeline end: the all-gather's completion — the
        // quantity scenario compositions charge to this phase. A consumer
        // GEMM (charged to the *next* sub-layer) may drain later; with one
        // present the stamp is the full drain so its spans stay covered.
        let stamp = if self.consumer.is_some() { total } else { self.ag_done };
        let timeline = self.r.take_timeline(stamp);
        AllGatherResult {
            total,
            ag_done: self.ag_done,
            step_ends: self.step_ends,
            consumer_done: self.consumer.as_ref().map(|c| c.done),
            counters: self.r.mem.counters,
            timeline,
            link_bytes: self.r.link_out.bytes_carried(),
        }
    }
}

/// Loopback driver (§5.1.1 mirror): one rank whose hop messages are
/// delivered back to itself. The multi-rank cluster engine
/// ([`crate::cluster::run_ag_cluster`]) reproduces this bit-for-bit in its
/// uniform configuration.
pub fn run_fused_ag(
    sys: &SystemConfig,
    bytes: u64,
    devices: u64,
    start: SimTime,
    policy: ArbPolicy,
    consumer: Option<ConsumerSpec>,
) -> AllGatherResult {
    run_fused_ag_opt(sys, bytes, devices, start, policy, consumer, false)
}

/// [`run_fused_ag`] with timeline tracing enabled; the result's `timeline`
/// carries the rank-0 trace (absolute times — the trigger offset is part
/// of the timeline). Every simulated quantity is bit-identical to the
/// untraced run.
#[deprecated(
    since = "0.2.0",
    note = "trace capture is an ExecOpts field now: run a FusedAg phase \
            through cluster::execute, or run_collective(traced = true)"
)]
pub fn run_fused_ag_traced(
    sys: &SystemConfig,
    bytes: u64,
    devices: u64,
    start: SimTime,
    policy: ArbPolicy,
    consumer: Option<ConsumerSpec>,
) -> AllGatherResult {
    run_fused_ag_opt(sys, bytes, devices, start, policy, consumer, true)
}

#[allow(clippy::too_many_arguments)]
fn run_fused_ag_opt(
    sys: &SystemConfig,
    bytes: u64,
    devices: u64,
    start: SimTime,
    policy: ArbPolicy,
    consumer: Option<ConsumerSpec>,
    traced: bool,
) -> AllGatherResult {
    let spec = AgRankSpec {
        bytes,
        devices,
        start,
        link: sys.link.clone(),
        policy,
        consumer,
    };
    let mut rank = AllGatherRank::new(sys, &spec);
    if traced {
        rank.enable_trace(0);
    }
    let mut msgs = Vec::new();
    while rank.step(&mut msgs) {
        for m in msgs.drain(..) {
            rank.deliver(&m);
        }
    }
    rank.into_result()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DType, SystemConfig};
    use crate::engine::collective_run::run_ag_baseline;
    use crate::gemm::{GemmShape, Tiling};

    const MB: u64 = 1 << 20;

    fn sys() -> SystemConfig {
        SystemConfig::table1()
    }

    fn run(bytes: u64, devices: u64, start: SimTime) -> AllGatherResult {
        run_fused_ag(&sys(), bytes, devices, start, ArbPolicy::T3Mca, None)
    }

    #[test]
    fn fused_ag_beats_cu_baseline() {
        let s = sys();
        for devices in [4u64, 8, 16] {
            let base = run_ag_baseline(&s, 64 * MB, devices, 80);
            let fused = run(64 * MB, devices, SimTime::ZERO);
            assert!(
                fused.ag_done < base.time,
                "devices={devices}: fused {} !< baseline {}",
                fused.ag_done,
                base.time
            );
        }
    }

    #[test]
    fn fused_ag_not_below_link_transfer_bound() {
        // The egress link still carries N-1 chunks serially.
        let s = sys();
        let n = 8u64;
        let fused = run(64 * MB, n, SimTime::ZERO);
        let bound = SimTime::transfer((n - 1) * (64 * MB / n), s.link.per_dir_bw_gbps);
        assert!(
            fused.ag_done >= bound,
            "fused {} below link bound {bound}",
            fused.ag_done
        );
    }

    #[test]
    fn cut_through_reads_only_the_own_chunk() {
        let s = sys();
        let n = 8u64;
        let chunk = 64 * MB / n;
        let fused = run(64 * MB, n, SimTime::ZERO);
        let slack = 64 * s.mem.txn_bytes;
        assert!(fused.counters.ag_reads >= chunk && fused.counters.ag_reads <= chunk + slack,
            "ag reads {} vs chunk {chunk}", fused.counters.ag_reads);
        // Stores: one chunk per receive step.
        let expect_writes = (n - 1) * chunk;
        assert!(fused.counters.ag_writes >= expect_writes
            && fused.counters.ag_writes <= expect_writes + slack * n,
            "ag writes {} vs {expect_writes}", fused.counters.ag_writes);
        let base = run_ag_baseline(&s, 64 * MB, n, 80);
        assert!(fused.counters.ag_reads < base.counters.ag_reads);
    }

    #[test]
    fn start_offset_shifts_the_whole_run() {
        let base = run(32 * MB, 4, SimTime::ZERO);
        let t0 = SimTime::us(91);
        let shifted = run(32 * MB, 4, t0);
        assert_eq!(shifted.ag_done, base.ag_done + t0);
        assert_eq!(shifted.total, base.total + t0);
        assert_eq!(shifted.counters, base.counters);
        for (a, b) in shifted.step_ends.iter().zip(&base.step_ends) {
            assert_eq!(*a, *b + t0);
        }
    }

    #[test]
    fn step_ends_monotone() {
        let res = run(64 * MB, 8, SimTime::ZERO);
        assert_eq!(res.step_ends.len(), 7);
        for w in res.step_ends.windows(2) {
            assert!(w[1] >= w[0], "step ends must not rewind");
        }
        assert!(res.ag_done >= *res.step_ends.last().unwrap());
    }

    #[test]
    fn works_for_two_ranks() {
        let res = run(16 * MB, 2, SimTime::ZERO);
        assert_eq!(res.step_ends.len(), 1);
        assert!(res.ag_done > SimTime::ZERO);
        assert!(res.consumer_done.is_none());
    }

    #[test]
    fn consumer_gemm_overlaps_and_contends() {
        let s = sys();
        let plan = StagePlan::new(
            GemmShape::new(4096, 2048, 512, DType::F16),
            Tiling::default(),
            &s.gpu,
        );
        let free = run(64 * MB, 8, SimTime::ZERO);
        let with = run_fused_ag(
            &s,
            64 * MB,
            8,
            SimTime::ZERO,
            ArbPolicy::T3Mca,
            Some(ConsumerSpec {
                plan: plan.clone(),
                write_mode: WriteMode::BypassLlc,
                compute_scale: 1.0,
            }),
        );
        let done = with.consumer_done.expect("consumer ran");
        assert!(done > SimTime::ZERO && done != SimTime::MAX);
        // Contention can only slow the AG, never speed it up.
        assert!(with.ag_done >= free.ag_done);
        // The consumer is gated on arrivals: it cannot retire before the
        // last chunk it needs has landed.
        assert!(done >= *with.step_ends.last().unwrap());
        // GEMM traffic is accounted on the compute classes.
        assert!(with.counters.gemm_reads > 0);
        assert_eq!(free.counters.gemm_reads, 0);
    }

    #[test]
    fn consumer_scale_stretches_consumer_not_ag_order() {
        let s = sys();
        let plan = StagePlan::new(
            GemmShape::new(2048, 1024, 256, DType::F16),
            Tiling::default(),
            &s.gpu,
        );
        let consumer = |scale: f64| {
            run_fused_ag(
                &s,
                32 * MB,
                4,
                SimTime::ZERO,
                ArbPolicy::T3Mca,
                Some(ConsumerSpec {
                    plan: plan.clone(),
                    write_mode: WriteMode::BypassLlc,
                    compute_scale: scale,
                }),
            )
        };
        let nominal = consumer(1.0);
        let slow = consumer(1.5);
        assert!(slow.consumer_done.unwrap() > nominal.consumer_done.unwrap());
    }
}
