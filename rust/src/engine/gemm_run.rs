//! Isolated producer-GEMM execution (baseline building block).
//!
//! Models the stage-by-stage execution of Section 2.5 / Figure 17(a): each
//! stage issues its input reads (overlapped with compute), then emits a
//! bursty write phase at stage end. Used for:
//! * the Sequential baseline's GEMM portion;
//! * the CU-split contention study (Figure 6) via `cus`;
//! * the Ideal-GEMM-RS-Overlap composition (max of isolated times).
//!
//! Like the collective engines, the GEMM is factored as a *per-rank state
//! machine* ([`GemmRank`]): an event-driven stage machine over its own
//! [`Runner`] that implements the same `step`/`deliver` protocol as
//! [`super::fused::FusedRank`] — it just never sends messages (an isolated
//! GEMM has no ring traffic). That makes the producer GEMM a first-class
//! [`crate::cluster::Collective`] phase: the cluster driver advances `tp`
//! independent skewed GEMMs through the same global event loop as every
//! other collective, and the loopback entry points below are one-rank
//! drivers over the identical machine.

use crate::config::{ArbPolicy, GpuConfig, SystemConfig};
use crate::gemm::traffic::{gemm_traffic, stage_reads, GemmTraffic, WriteMode};
use crate::gemm::StagePlan;
use crate::hw::hbm::{TrafficClass, TxnKind};
use crate::hw::mc::Stream;
use crate::sim::stats::DramCounters;
use crate::sim::time::SimTime;
use crate::trace::{Lane, RankTrace, SpanLabel};

use super::{Ev, GroupTag, Runner};

/// Result of one isolated GEMM run.
#[derive(Debug, Clone)]
pub struct GemmRunResult {
    /// Kernel retirement time.
    pub time: SimTime,
    /// DRAM traffic counters for the run.
    pub counters: DramCounters,
    /// The analytic traffic estimate the run was driven by.
    pub traffic: GemmTraffic,
    /// Per-stage end times (diagnostics / fused-engine validation).
    pub stage_ends: Vec<SimTime>,
    /// Timeline trace (when the runner had tracing enabled). The stamped
    /// end is the kernel's retirement (`time`), not the write-drain tail —
    /// matching the result's composition semantics.
    pub timeline: Option<RankTrace>,
}

/// Construction parameters of one [`GemmRank`].
#[derive(Debug, Clone)]
pub struct GemmRankSpec {
    /// The GEMM's stage plan.
    pub plan: StagePlan,
    /// CUs granted to the kernel.
    pub cus: u32,
    /// Write mode for the kernel's stores.
    pub mode: WriteMode,
    /// Per-rank compute slowdown (1.0 = nominal; the cluster skew model).
    pub compute_scale: f64,
    /// Kernel launch time (offset composition; `SimTime::ZERO` submits the
    /// stage-0 reads immediately, exactly as the legacy entry points did).
    pub start: SimTime,
}

/// Messages of an isolated GEMM rank: there are none. The empty enum lets
/// [`GemmRank`] share the rank-machine driver protocol with the
/// communicating machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmMsg {}

/// One rank's isolated producer GEMM as an event-driven stage machine over
/// its own [`Runner`]. Drive with [`GemmRank::step`] like the other rank
/// machines; [`GemmRank::deliver`] is a no-op (no ring traffic).
pub struct GemmRank {
    r: Runner,
    plan: StagePlan,
    gpu: GpuConfig,
    eff: f64,
    cus: u32,
    scale: f64,
    write_kind: TxnKind,
    traffic: GemmTraffic,
    started: bool,
    stage: u64,
    compute_done: bool,
    stage_ends: Vec<SimTime>,
    last_stage_end: SimTime,
    tags: Vec<(GroupTag, SimTime)>,
}

impl GemmRank {
    /// Build one rank's machine from its spec.
    pub fn new(sys: &SystemConfig, spec: &GemmRankSpec) -> Self {
        Self::from_runner(Runner::new(sys, ArbPolicy::ComputePriority), spec)
    }

    /// Build the machine over an existing runner (lets callers pre-load
    /// background traffic or reuse MCA settings).
    pub fn from_runner(mut r: Runner, spec: &GemmRankSpec) -> Self {
        debug_assert!(spec.compute_scale >= 1.0);
        let traffic = gemm_traffic(&spec.plan, &r.sys.mem, spec.mode);
        let write_kind = match spec.mode {
            WriteMode::ThroughLlc => TxnKind::Write,
            WriteMode::BypassLlc => TxnKind::NmcUpdate,
        };
        let gpu = r.sys.gpu.clone();
        let eff = gpu.gemm_efficiency;
        let started = spec.start.is_zero();
        if started {
            // Immediate submission: bit-identical to the legacy closed loop.
            Self::submit_stage(&mut r, &spec.plan, traffic.dram_reads, 0);
        } else {
            r.q.schedule(spec.start, Ev::Marker { step: 0, what: 0 });
        }
        GemmRank {
            r,
            plan: spec.plan.clone(),
            gpu,
            eff,
            cus: spec.cus,
            scale: spec.compute_scale,
            write_kind,
            traffic,
            started,
            stage: 0,
            compute_done: false,
            stage_ends: Vec::new(),
            last_stage_end: SimTime::ZERO,
            tags: Vec::new(),
        }
    }

    fn submit_stage(r: &mut Runner, plan: &StagePlan, dram_reads: u64, s: u64) {
        let bytes = stage_reads(plan, dram_reads, s).max(r.sys.mem.txn_bytes);
        r.submit_tagged(
            bytes,
            TxnKind::Read,
            Stream::Compute,
            TrafficClass::GemmRead,
            GroupTag::StageReads(s),
        );
    }

    /// Record this rank's timeline (`t3::trace`): CU stage compute and the
    /// DRAM service lanes. Purely observational.
    pub fn enable_trace(&mut self, rank: u64) {
        self.r.enable_trace(rank);
    }

    /// [`GemmRank::enable_trace`] with an explicit [`crate::trace::SinkMode`]
    /// (metrics mode folds spans into per-lane aggregates as they land).
    pub fn enable_trace_with(&mut self, rank: u64, mode: crate::trace::SinkMode) {
        self.r.enable_trace_with(rank, mode);
    }

    /// Time of this rank's next pending event.
    pub fn next_time(&self) -> Option<SimTime> {
        self.r.q.peek_time()
    }

    /// A GEMM rank receives nothing; present for driver uniformity.
    pub fn deliver(&mut self, msg: &GemmMsg) {
        match *msg {}
    }

    /// Process one event. Returns `false` when the calendar is empty.
    pub fn step(&mut self, _out: &mut Vec<GemmMsg>) -> bool {
        let Some((t, ev)) = self.r.next_event() else {
            return false;
        };
        let mut tags = std::mem::take(&mut self.tags);
        self.r.drain_tags(&mut tags);
        for (tag, blocked) in tags.drain(..) {
            if let GroupTag::StageReads(s) = tag {
                debug_assert_eq!(s, self.stage);
                // Reads drained: the compute phase runs to completion,
                // extended by the unhidden fraction of the head-of-line
                // stalls its loads suffered behind comm traffic.
                let ct = self.plan.stage_compute_time(s, &self.gpu, self.cus, self.eff);
                let ct = if self.scale != 1.0 { ct * self.scale } else { ct };
                let stall = blocked * self.gpu.stall_unhidden;
                self.r.sink.span(Lane::CuCompute, t, t + ct + stall, 0, SpanLabel::Stage(s));
                self.r.q.schedule_in(ct + stall, Ev::StageCompute(s));
            }
        }
        self.tags = tags;

        match ev {
            Ev::Marker { step: 0, what: 0 } if !self.started => {
                self.started = true;
                Self::submit_stage(&mut self.r, &self.plan, self.traffic.dram_reads, 0);
            }
            Ev::StageCompute(s) => {
                debug_assert_eq!(s, self.stage);
                self.compute_done = true;
            }
            _ => {}
        }

        if self.compute_done {
            // Stage end: bursty write phase, then next stage begins.
            let wgs = self.plan.wgs_in_stage(self.stage);
            let bytes = wgs * self.plan.wg_out_bytes();
            self.r
                .submit_untagged(bytes, self.write_kind, Stream::Compute, TrafficClass::GemmWrite);
            self.stage_ends.push(t);
            self.last_stage_end = t;
            self.stage += 1;
            self.compute_done = false;
            if self.stage < self.plan.num_stages {
                Self::submit_stage(&mut self.r, &self.plan, self.traffic.dram_reads, self.stage);
            }
        }
        true
    }

    /// Consume the drained rank into its result.
    pub fn into_result(self) -> GemmRunResult {
        let (res, _r) = self.into_result_with_runner();
        res
    }

    fn into_result_with_runner(mut self) -> (GemmRunResult, Runner) {
        debug_assert!(self.r.mem.idle());
        debug_assert_eq!(self.stage, self.plan.num_stages);
        let timeline = self.r.take_timeline(self.last_stage_end);
        let res = GemmRunResult {
            // The kernel completes when its last stage retires; the write
            // drain tail overlaps whatever follows.
            time: self.last_stage_end,
            counters: self.r.mem.counters,
            traffic: self.traffic,
            stage_ends: self.stage_ends,
            timeline,
        };
        (res, self.r)
    }

    fn run_to_completion(&mut self) {
        let mut msgs = Vec::new();
        while self.step(&mut msgs) {}
    }
}

/// Run one GEMM in isolation on `cus` compute units.
pub fn run_gemm(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
) -> GemmRunResult {
    run_gemm_scaled(sys, plan, cus, mode, 1.0)
}

/// [`run_gemm`] with a per-rank compute slowdown factor (`1.0` = nominal;
/// the cluster skew model stretches a straggler's stage compute times).
pub fn run_gemm_scaled(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
    compute_scale: f64,
) -> GemmRunResult {
    let mut rank = GemmRank::new(
        sys,
        &GemmRankSpec {
            plan: plan.clone(),
            cus,
            mode,
            compute_scale,
            start: SimTime::ZERO,
        },
    );
    rank.run_to_completion();
    rank.into_result()
}

/// [`run_gemm`] with timeline tracing enabled (rank 0). Bit-identical to
/// the untraced run in every simulated quantity.
#[deprecated(
    since = "0.2.0",
    note = "trace capture is an ExecOpts field now: run a Gemm phase through \
            cluster::execute, or enable_trace on a GemmRank directly"
)]
pub fn run_gemm_traced(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
) -> GemmRunResult {
    run_gemm_traced_impl(sys, plan, cus, mode, 1.0, 0)
}

/// [`run_gemm_scaled`] with timeline tracing enabled as rank `rank` (the
/// cluster's per-rank skewed GEMMs).
#[deprecated(
    since = "0.2.0",
    note = "trace capture is an ExecOpts field now: run a Gemm phase through \
            cluster::execute, or enable_trace on a GemmRank directly"
)]
pub fn run_gemm_scaled_traced(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
    compute_scale: f64,
    rank: u64,
) -> GemmRunResult {
    run_gemm_traced_impl(sys, plan, cus, mode, compute_scale, rank)
}

fn run_gemm_traced_impl(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
    compute_scale: f64,
    rank: u64,
) -> GemmRunResult {
    let mut r = GemmRank::new(
        sys,
        &GemmRankSpec {
            plan: plan.clone(),
            cus,
            mode,
            compute_scale,
            start: SimTime::ZERO,
        },
    );
    r.enable_trace(rank);
    r.run_to_completion();
    r.into_result()
}

/// Run a GEMM on an existing runner (lets callers pre-load background
/// traffic or reuse MCA settings).
pub fn run_gemm_on(
    r: &mut Runner,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
) -> GemmRunResult {
    run_gemm_on_scaled(r, plan, cus, mode, 1.0)
}

fn run_gemm_on_scaled(
    r: &mut Runner,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
    compute_scale: f64,
) -> GemmRunResult {
    // Move the caller's runner into the rank machine and hand it back after
    // the drain, so pre-loaded state survives the run.
    let policy = r.mem.policy();
    let sys = r.sys.clone();
    let owned = std::mem::replace(r, Runner::new(&sys, policy));
    let mut rank = GemmRank::from_runner(
        owned,
        &GemmRankSpec {
            plan: plan.clone(),
            cus,
            mode,
            compute_scale,
            start: SimTime::ZERO,
        },
    );
    rank.run_to_completion();
    let (res, runner) = rank.into_result_with_runner();
    *r = runner;
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DType, SystemConfig};
    use crate::gemm::{GemmShape, Tiling};

    fn plan(m: u64, n: u64, k: u64) -> StagePlan {
        StagePlan::new(
            GemmShape::new(m, n, k, DType::F16),
            Tiling::default(),
            &SystemConfig::table1().gpu,
        )
    }

    #[test]
    fn compute_bound_gemm_matches_roofline() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128); // T-NLG FC-2 TP=8
        let res = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        let roofline = p.shape.flops() as f64 / sys.gpu.sustained_gemm_flops(DType::F16);
        let sim = res.time.as_secs_f64();
        let ratio = sim / roofline;
        // Event model adds read-phase serialization at stage boundaries but
        // should stay near the compute roofline for a compute-bound GEMM.
        assert!((0.95..1.4).contains(&ratio), "sim/roofline = {ratio}");
    }

    #[test]
    fn memory_bound_gemm_tracks_bandwidth() {
        let sys = SystemConfig::table1();
        // Skinny K: little compute, streaming reads dominate.
        let p = plan(16384, 3072, 64);
        let res = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        let bytes = res.traffic.dram_reads + res.traffic.dram_writes;
        let bw_floor = bytes as f64 / (sys.mem.total_bw_gbps * 1e9);
        let sim = res.time.as_secs_f64();
        assert!(sim >= bw_floor * 0.8, "sim {sim} < bw floor {bw_floor}");
        assert!(sim <= bw_floor * 2.5, "sim {sim} >> bw floor {bw_floor}");
    }

    #[test]
    fn fewer_cus_slower() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let t80 = run_gemm(&sys, &p, 80, WriteMode::BypassLlc).time;
        let t72 = run_gemm(&sys, &p, 72, WriteMode::BypassLlc).time;
        let t64 = run_gemm(&sys, &p, 64, WriteMode::BypassLlc).time;
        assert!(t72 > t80);
        assert!(t64 > t72);
        // Fig 6: 64-CU GEMMs ~21% slower than 80-CU (compute scales with
        // CUs, the read phases do not).
        let slowdown = t64.as_ps() as f64 / t80.as_ps() as f64;
        assert!((1.12..1.3).contains(&slowdown), "slowdown {slowdown}");
    }

    #[test]
    fn counters_match_traffic_model() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 4096, 1024);
        let res = run_gemm(&sys, &p, 80, WriteMode::ThroughLlc);
        // Counter bytes are txn-rounded; stay within a txn per stage/burst.
        let slack = (p.num_stages + 1) * sys.mem.txn_bytes;
        assert!(res.counters.gemm_reads >= res.traffic.dram_reads);
        assert!(res.counters.gemm_reads <= res.traffic.dram_reads + slack);
        assert!(res.counters.gemm_writes >= res.traffic.dram_writes);
        assert!(res.counters.gemm_writes <= res.traffic.dram_writes + slack);
        assert_eq!(res.counters.rs_reads, 0);
    }

    #[test]
    fn compute_scale_stretches_the_run() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 4096, 1024);
        let nominal = run_gemm_scaled(&sys, &p, 80, WriteMode::BypassLlc, 1.0);
        let slow = run_gemm_scaled(&sys, &p, 80, WriteMode::BypassLlc, 1.5);
        assert!(slow.time > nominal.time);
        // Scale 1.0 is the plain path, bit-for-bit.
        let plain = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        assert_eq!(plain.time, nominal.time);
        assert_eq!(plain.stage_ends, nominal.stage_ends);
    }

    #[test]
    fn stage_ends_monotone_and_complete() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 532);
        let res = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        assert_eq!(res.stage_ends.len(), p.num_stages as usize);
        for w in res.stage_ends.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert_eq!(*res.stage_ends.last().unwrap(), res.time);
    }

    #[test]
    fn start_offset_shifts_the_whole_run() {
        // The rank machine is shift-invariant: launching at T ends exactly
        // T later (the property phase-offset composition relies on).
        let sys = SystemConfig::table1();
        let p = plan(4096, 2048, 512);
        let base = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        let t0 = SimTime::us(73);
        let mut rank = GemmRank::new(
            &sys,
            &GemmRankSpec {
                plan: p.clone(),
                cus: 80,
                mode: WriteMode::BypassLlc,
                compute_scale: 1.0,
                start: t0,
            },
        );
        rank.run_to_completion();
        let shifted = rank.into_result();
        assert_eq!(shifted.time, base.time + t0);
        assert_eq!(shifted.counters, base.counters);
        for (a, b) in shifted.stage_ends.iter().zip(&base.stage_ends) {
            assert_eq!(*a, *b + t0);
        }
    }

    #[test]
    fn rank_machine_matches_legacy_entry_point() {
        // The event-driven machine is the legacy closed loop, bit-for-bit.
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let legacy = run_gemm(&sys, &p, 80, WriteMode::ThroughLlc);
        let mut rank = GemmRank::new(
            &sys,
            &GemmRankSpec {
                plan: p.clone(),
                cus: 80,
                mode: WriteMode::ThroughLlc,
                compute_scale: 1.0,
                start: SimTime::ZERO,
            },
        );
        rank.run_to_completion();
        let machine = rank.into_result();
        assert_eq!(machine.time, legacy.time);
        assert_eq!(machine.stage_ends, legacy.stage_ends);
        assert_eq!(machine.counters, legacy.counters);
    }
}
