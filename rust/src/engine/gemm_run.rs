//! Isolated producer-GEMM execution (baseline building block).
//!
//! Models the stage-by-stage execution of Section 2.5 / Figure 17(a): each
//! stage issues its input reads (overlapped with compute), then emits a
//! bursty write phase at stage end. Used for:
//! * the Sequential baseline's GEMM portion;
//! * the CU-split contention study (Figure 6) via `cus`;
//! * the Ideal-GEMM-RS-Overlap composition (max of isolated times).

use crate::config::{ArbPolicy, SystemConfig};
use crate::gemm::traffic::{gemm_traffic, stage_reads, GemmTraffic, WriteMode};
use crate::gemm::StagePlan;
use crate::hw::hbm::{TrafficClass, TxnKind};
use crate::hw::mc::Stream;
use crate::sim::stats::DramCounters;
use crate::sim::time::SimTime;
use crate::trace::{Lane, RankTrace, SpanLabel};

use super::{Ev, GroupTag, Runner};

/// Result of one isolated GEMM run.
#[derive(Debug, Clone)]
pub struct GemmRunResult {
    pub time: SimTime,
    pub counters: DramCounters,
    pub traffic: GemmTraffic,
    /// Per-stage end times (diagnostics / fused-engine validation).
    pub stage_ends: Vec<SimTime>,
    /// Timeline trace (when the runner had tracing enabled). The stamped
    /// end is the kernel's retirement (`time`), not the write-drain tail —
    /// matching the result's composition semantics.
    pub timeline: Option<RankTrace>,
}

/// Run one GEMM in isolation on `cus` compute units.
pub fn run_gemm(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
) -> GemmRunResult {
    run_gemm_scaled(sys, plan, cus, mode, 1.0)
}

/// [`run_gemm`] with a per-rank compute slowdown factor (`1.0` = nominal;
/// the cluster skew model stretches a straggler's stage compute times).
pub fn run_gemm_scaled(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
    compute_scale: f64,
) -> GemmRunResult {
    let mut r = Runner::new(sys, ArbPolicy::ComputePriority);
    run_gemm_on_scaled(&mut r, plan, cus, mode, compute_scale)
}

/// [`run_gemm`] with timeline tracing enabled (rank 0). Bit-identical to
/// the untraced run in every simulated quantity.
pub fn run_gemm_traced(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
) -> GemmRunResult {
    run_gemm_scaled_traced(sys, plan, cus, mode, 1.0, 0)
}

/// [`run_gemm_scaled`] with timeline tracing enabled as rank `rank` (the
/// cluster's per-rank skewed GEMMs).
pub fn run_gemm_scaled_traced(
    sys: &SystemConfig,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
    compute_scale: f64,
    rank: u64,
) -> GemmRunResult {
    let mut r = Runner::new(sys, ArbPolicy::ComputePriority);
    r.enable_trace(rank);
    run_gemm_on_scaled(&mut r, plan, cus, mode, compute_scale)
}

/// Run a GEMM on an existing runner (lets callers pre-load background
/// traffic or reuse MCA settings).
pub fn run_gemm_on(
    r: &mut Runner,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
) -> GemmRunResult {
    run_gemm_on_scaled(r, plan, cus, mode, 1.0)
}

fn run_gemm_on_scaled(
    r: &mut Runner,
    plan: &StagePlan,
    cus: u32,
    mode: WriteMode,
    compute_scale: f64,
) -> GemmRunResult {
    debug_assert!(compute_scale >= 1.0);
    let traffic = gemm_traffic(plan, &r.sys.mem, mode);
    let write_kind = match mode {
        WriteMode::ThroughLlc => TxnKind::Write,
        WriteMode::BypassLlc => TxnKind::NmcUpdate,
    };
    let gpu = r.sys.gpu.clone();
    let eff = gpu.gemm_efficiency;

    let mut stage_ends = Vec::with_capacity(plan.num_stages as usize);
    let mut tags = Vec::new();

    // Stage state machine: a stage's read phase must drain before its
    // compute phase can retire — GPU WGs stall until their tiles arrive,
    // and there is limited latency hiding across a stage boundary. This is
    // the coupling through which bursty RS traffic slows the producer
    // (Figure 17b).
    let mut stage = 0u64;
    let mut compute_done = false;

    let start_stage = |r: &mut Runner, s: u64| {
        let bytes = stage_reads(plan, traffic.dram_reads, s).max(r.sys.mem.txn_bytes);
        r.submit_tagged(
            bytes,
            TxnKind::Read,
            Stream::Compute,
            TrafficClass::GemmRead,
            GroupTag::StageReads(s),
        );
    };
    start_stage(r, 0);

    let mut last_stage_end = SimTime::ZERO;
    while let Some((t, ev)) = r.next_event() {
        r.drain_tags(&mut tags);
        for (tag, blocked) in tags.drain(..) {
            if let GroupTag::StageReads(s) = tag {
                debug_assert_eq!(s, stage);
                // Reads drained: the compute phase runs to completion,
                // extended by the unhidden fraction of the head-of-line
                // stalls its loads suffered behind comm traffic.
                let ct = plan.stage_compute_time(s, &gpu, cus, eff);
                let ct = if compute_scale != 1.0 {
                    ct * compute_scale
                } else {
                    ct
                };
                let stall = blocked * gpu.stall_unhidden;
                r.sink.span(Lane::CuCompute, t, t + ct + stall, 0, SpanLabel::Stage(s));
                r.q.schedule_in(ct + stall, Ev::StageCompute(s));
            }
        }
        if let Ev::StageCompute(s) = ev {
            debug_assert_eq!(s, stage);
            compute_done = true;
        }
        if compute_done {
            // Stage end: bursty write phase, then next stage begins.
            let wgs = plan.wgs_in_stage(stage);
            let bytes = wgs * plan.wg_out_bytes();
            r.submit_untagged(bytes, write_kind, Stream::Compute, TrafficClass::GemmWrite);
            stage_ends.push(t);
            last_stage_end = t;
            stage += 1;
            compute_done = false;
            if stage < plan.num_stages {
                start_stage(r, stage);
            }
        }
    }
    debug_assert!(r.mem.idle());
    debug_assert_eq!(stage, plan.num_stages);

    let timeline = r.take_timeline(last_stage_end);
    GemmRunResult {
        // The kernel completes when its last stage retires; the write
        // drain tail overlaps whatever follows.
        time: last_stage_end,
        counters: r.mem.counters,
        traffic,
        stage_ends,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DType, SystemConfig};
    use crate::gemm::{GemmShape, Tiling};

    fn plan(m: u64, n: u64, k: u64) -> StagePlan {
        StagePlan::new(
            GemmShape::new(m, n, k, DType::F16),
            Tiling::default(),
            &SystemConfig::table1().gpu,
        )
    }

    #[test]
    fn compute_bound_gemm_matches_roofline() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128); // T-NLG FC-2 TP=8
        let res = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        let roofline = p.shape.flops() as f64 / sys.gpu.sustained_gemm_flops(DType::F16);
        let sim = res.time.as_secs_f64();
        let ratio = sim / roofline;
        // Event model adds read-phase serialization at stage boundaries but
        // should stay near the compute roofline for a compute-bound GEMM.
        assert!((0.95..1.4).contains(&ratio), "sim/roofline = {ratio}");
    }

    #[test]
    fn memory_bound_gemm_tracks_bandwidth() {
        let sys = SystemConfig::table1();
        // Skinny K: little compute, streaming reads dominate.
        let p = plan(16384, 3072, 64);
        let res = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        let bytes = res.traffic.dram_reads + res.traffic.dram_writes;
        let bw_floor = bytes as f64 / (sys.mem.total_bw_gbps * 1e9);
        let sim = res.time.as_secs_f64();
        assert!(sim >= bw_floor * 0.8, "sim {sim} < bw floor {bw_floor}");
        assert!(sim <= bw_floor * 2.5, "sim {sim} >> bw floor {bw_floor}");
    }

    #[test]
    fn fewer_cus_slower() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let t80 = run_gemm(&sys, &p, 80, WriteMode::BypassLlc).time;
        let t72 = run_gemm(&sys, &p, 72, WriteMode::BypassLlc).time;
        let t64 = run_gemm(&sys, &p, 64, WriteMode::BypassLlc).time;
        assert!(t72 > t80);
        assert!(t64 > t72);
        // Fig 6: 64-CU GEMMs ~21% slower than 80-CU (compute scales with
        // CUs, the read phases do not).
        let slowdown = t64.as_ps() as f64 / t80.as_ps() as f64;
        assert!((1.12..1.3).contains(&slowdown), "slowdown {slowdown}");
    }

    #[test]
    fn counters_match_traffic_model() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 4096, 1024);
        let res = run_gemm(&sys, &p, 80, WriteMode::ThroughLlc);
        // Counter bytes are txn-rounded; stay within a txn per stage/burst.
        let slack = (p.num_stages + 1) * sys.mem.txn_bytes;
        assert!(res.counters.gemm_reads >= res.traffic.dram_reads);
        assert!(res.counters.gemm_reads <= res.traffic.dram_reads + slack);
        assert!(res.counters.gemm_writes >= res.traffic.dram_writes);
        assert!(res.counters.gemm_writes <= res.traffic.dram_writes + slack);
        assert_eq!(res.counters.rs_reads, 0);
    }

    #[test]
    fn compute_scale_stretches_the_run() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 4096, 1024);
        let nominal = run_gemm_scaled(&sys, &p, 80, WriteMode::BypassLlc, 1.0);
        let slow = run_gemm_scaled(&sys, &p, 80, WriteMode::BypassLlc, 1.5);
        assert!(slow.time > nominal.time);
        // Scale 1.0 is the plain path, bit-for-bit.
        let plain = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        assert_eq!(plain.time, nominal.time);
        assert_eq!(plain.stage_ends, nominal.stage_ends);
    }

    #[test]
    fn stage_ends_monotone_and_complete() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 532);
        let res = run_gemm(&sys, &p, 80, WriteMode::BypassLlc);
        assert_eq!(res.stage_ends.len(), p.num_stages as usize);
        for w in res.stage_ends.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert_eq!(*res.stage_ends.last().unwrap(), res.time);
    }
}
