//! The T3 fused GEMM + ring reduce-scatter engine (Section 4, Figure 7-8).
//!
//! The engine is factored as a *per-rank state machine* ([`FusedRank`]):
//! one device's GEMM wavefront timeline, tracker/DMA trigger state, and
//! HBM/MC contention model, which communicates with its ring neighbors
//! only through explicit [`FusedMsg`] ingress-window messages. Two drivers
//! exist:
//!
//! * [`run_fused_gemm_rs`] — the paper's §5.1.1 methodology: model *one*
//!   GPU in detail and mirror its egress timeline into its ingress
//!   (homogeneous devices, staggered WG scheduling). Implemented as a
//!   single `FusedRank` whose outbound messages are looped back to itself.
//! * [`crate::cluster`] — the multi-rank engine: `tp` interacting
//!   `FusedRank`s whose messages travel to the actual downstream neighbor
//!   over per-edge links. With no skew and a single-tier topology every
//!   rank behaves identically, so the loopback mirror *is* the cluster's
//!   special case; with skew/stragglers/two-tier links, a slow rank or
//!   congested hop delays exactly the chunks that transit it.
//!
//! One rank's timeline:
//!
//! * The GEMM executes stage by stage, its WGs reordered chunk-first by the
//!   staggered `ChunkPlan`. Stage reads flow through the MC *compute*
//!   stream; stage writes land according to the `OutputMap`:
//!   - position 0 (remote-mapped): fine-grained stores straight onto the
//!     egress link (no local DRAM traffic — §6.2's "fusion eliminates local
//!     writes from GEMM's first stage");
//!   - other positions: local near-memory op-and-store updates.
//! * Incoming DMA updates for position `p` arrive on the upstream
//!   neighbor's egress window for *its* position `p-1` (the same chunk, by
//!   the stagger) plus the hop latency, entering the MC *comm* stream as
//!   NMC updates.
//! * When a position's local updates AND incoming updates have all landed
//!   (the Tracker condition — threshold = 2 updates/element for ring-RS),
//!   the pre-programmed DMA fires: chunk reads on the comm stream + an
//!   egress window; the downstream neighbor paces the matching ingress.
//! * The final position is the device's fully-reduced chunk; the run ends
//!   when it is reduced and all egress/ingress traffic has drained.
//!
//! Contention between the GEMM's reads and the RS's bursty updates/reads is
//! resolved by the configured `ArbPolicy` — `RoundRobin` reproduces the
//! paper's T3 configuration, `T3Mca` adds the §4.5 arbitration policy.

use crate::addrspace::{ChunkMap, DmaTable, OutputMap};
use crate::config::{ArbPolicy, GpuConfig, LinkConfig, SystemConfig};
use crate::gemm::traffic::{gemm_bytes_per_flop, gemm_traffic, stage_reads, WriteMode};
use crate::gemm::{ChunkPlan, StagePlan};
use crate::hw::hbm::{GroupId, TrafficClass, Txn, TxnKind};
use crate::hw::mc::{intensity_class, Stream};
use crate::sim::stats::DramCounters;
use crate::sim::time::SimTime;
use crate::trace::{DepKind, InstantKind, Lane, RankTrace, SinkMode, SpanLabel};

use super::{Ev, GroupTag, Runner, PACE_BATCH};

/// Result of a fused GEMM-RS run (one rank).
#[derive(Debug, Clone)]
pub struct FusedResult {
    /// End-to-end fused time (GEMM + RS fully overlapped + drain).
    pub total: SimTime,
    /// When the GEMM's last stage retired (to quantify GEMM slowdown
    /// under contention, Figure 17).
    pub gemm_time: SimTime,
    /// Retirement time of every GEMM stage in order (monotone; the last
    /// entry equals `gemm_time`). Slice-decomposed collectives derive
    /// retired-WG prefix triggers from these.
    pub stage_ends: Vec<SimTime>,
    /// Tracker-completion time per position.
    pub tracker_done: Vec<SimTime>,
    /// When each position's outbound transfer fully left the rank
    /// (egress window + DMA reads complete); `SimTime::MAX` for the local
    /// final chunk, which is never sent.
    pub sent_done: Vec<SimTime>,
    /// DRAM traffic counters for the run.
    pub counters: DramCounters,
    /// Peak concurrently-live tracker WF-tiles (hardware budget check).
    pub tracker_peak_tiles: u64,
    /// Figure-17 traffic trace (when `FusedOpts::trace_bin` is set).
    pub trace: Option<crate::hw::hbm::TrafficTrace>,
    /// Timeline trace (when [`FusedRank::enable_trace`] was called).
    pub timeline: Option<RankTrace>,
    /// Total bytes the egress link carried (trace reconciliation).
    pub link_bytes: u64,
}

impl FusedResult {
    /// When this rank can launch a fused all-gather
    /// ([`crate::engine::allgather`]): its own chunk is fully reduced
    /// (final tracker completion) *and* its egress port has drained the
    /// RS's remaining windows — the AG shares the physical link, so an
    /// earlier launch would double-book its bandwidth.
    pub fn ag_trigger(&self) -> SimTime {
        let reduced = *self.tracker_done.last().expect("ring has positions");
        let egress_free = self
            .sent_done
            .iter()
            .copied()
            .filter(|&t| t != SimTime::MAX)
            .max()
            .unwrap_or(SimTime::ZERO);
        reduced.max(egress_free)
    }
}

/// Options for a fused run.
#[derive(Debug, Clone)]
pub struct FusedOpts {
    /// MC arbitration between GEMM reads and collective traffic.
    pub policy: ArbPolicy,
    /// Producer write mode for the GEMM's local (non-remote) stores. T3's
    /// default is the uncached NMC bypass (§4.3); `ThroughLlc` models a
    /// fused producer whose writes still allocate, isolating the overlap
    /// benefit from the cache benefit.
    pub write_mode: WriteMode,
    /// Record a Figure-17 traffic trace with this bin size.
    pub trace_bin: Option<SimTime>,
}

impl Default for FusedOpts {
    fn default() -> Self {
        FusedOpts {
            policy: ArbPolicy::T3Mca,
            write_mode: WriteMode::BypassLlc,
            trace_bin: None,
        }
    }
}

/// A cross-rank ring message of the fused engine: the sender reserved an
/// egress window on its downstream link; the receiver paces the matching
/// ingress (as NMC updates through its MC comm stream) across the same
/// window. `pos` is the *receiver's* local chunk position — by the ring
/// stagger, the sender's position `p` chunk is the receiver's `p+1`.
/// `start`/`end` already include the hop latency of the edge the transfer
/// crossed (the sender knows its egress link).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusedMsg {
    /// One stage-segment of fine-grained remote stores (the sender's
    /// remote-mapped position 0): `wgs` workgroups of a chunk totalling
    /// `of_total` workgroups, so the receiver can pace a proportional
    /// share of the chunk's ingress across this segment's window.
    Segment {
        pos: u32,
        wgs: u64,
        of_total: u64,
        start: SimTime,
        end: SimTime,
    },
    /// A tracker-triggered DMA of a full (partially reduced) chunk.
    Dma { pos: u32, start: SimTime, end: SimTime },
}

impl FusedMsg {
    /// Receiver-local chunk position this message feeds.
    pub fn pos(&self) -> u32 {
        match *self {
            FusedMsg::Segment { pos, .. } | FusedMsg::Dma { pos, .. } => pos,
        }
    }
}

/// Per-stage write segments: (position, wg count).
fn stage_segments(plan: &StagePlan, chunks: &ChunkPlan) -> Vec<Vec<(u32, u64)>> {
    let n = chunks.devices as usize;
    // WG count processed per position, in processing order.
    let pos_wgs: Vec<u64> = (0..n)
        .map(|p| chunks.chunk_wgs[chunks.chunk_order[p] as usize])
        .collect();
    let mut segments = vec![Vec::new(); plan.num_stages as usize];
    let mut pos = 0usize;
    let mut left_in_pos = pos_wgs[0];
    for (s, seg) in segments.iter_mut().enumerate() {
        let mut left_in_stage = plan.wgs_in_stage(s as u64);
        while left_in_stage > 0 {
            let take = left_in_stage.min(left_in_pos);
            seg.push((pos as u32, take));
            left_in_stage -= take;
            left_in_pos -= take;
            if left_in_pos == 0 && pos + 1 < n {
                pos += 1;
                left_in_pos = pos_wgs[pos];
            }
        }
    }
    segments
}

/// One rank of the fused GEMM + ring-RS engine: an event-driven state
/// machine over its own [`Runner`] (memory system + calendar + egress
/// link). Drive it by alternating [`FusedRank::step`] (process one event,
/// collect outbound messages for the downstream neighbor) and
/// [`FusedRank::deliver`] (apply an upstream neighbor's message).
pub struct FusedRank {
    r: Runner,
    plan: StagePlan,
    chunks: ChunkPlan,
    map: OutputMap,
    dma: DmaTable,
    n: usize,
    gpu: GpuConfig,
    eff: f64,
    /// Per-rank compute slowdown (1.0 = nominal; the cluster skew model).
    compute_scale: f64,
    dram_reads: u64,

    // ---- per-position bookkeeping ----
    seg_to_come: Vec<u32>,
    groups_pending: Vec<u32>,
    send_conditions: Vec<u8>,
    local_done: Vec<bool>,
    ingress_done: Vec<bool>,
    ingress_scheduled: Vec<bool>,
    ingress_groups: Vec<GroupId>,
    tracker_done: Vec<SimTime>,
    sent_done: Vec<SimTime>,
    /// Ingress transactions still to pace per receiving position.
    ingress_left: Vec<u64>,
    /// Remaining WGs of the upstream sender's remote-mapped chunk
    /// (established by the first `Segment` message's `of_total`).
    sender_wgs_left: Option<u64>,

    // ---- GEMM stage machine ----
    stage: u64,
    stage_compute_done: bool,
    gemm_time: SimTime,
    stage_ends: Vec<SimTime>,

    // scratch (reused across events to keep the hot loop allocation-free)
    tags: Vec<(GroupTag, SimTime)>,
    newly_tracker_done: Vec<usize>,
}

impl FusedRank {
    /// Build rank `rank` of `devices` and submit its stage-0 reads.
    /// `link` is the rank's egress edge (to its downstream neighbor);
    /// `compute_scale >= 1.0` slows its GEMM stages (skew model).
    pub fn new(
        sys: &SystemConfig,
        plan: &StagePlan,
        devices: u64,
        rank: u64,
        opts: &FusedOpts,
        compute_scale: f64,
        link: LinkConfig,
    ) -> Self {
        let chunks = ChunkPlan::new(plan, devices, rank);
        let map = OutputMap::ring_reduce_scatter(&chunks, rank);
        let dma = DmaTable::program(&map, &chunks);
        let n = devices as usize;
        let traffic = gemm_traffic(plan, &sys.mem, opts.write_mode);

        let mut r = Runner::with_link(sys, opts.policy, link);
        if let Some(bin) = opts.trace_bin {
            r.mem.trace = Some(crate::hw::hbm::TrafficTrace::new(bin));
        }
        // MCA threshold class from the producer's memory intensity (§6.1.3).
        let machine_balance =
            sys.mem.total_bw_gbps * 1e9 / sys.gpu.sustained_gemm_flops(plan.shape.dtype);
        let class = intensity_class(
            gemm_bytes_per_flop(plan, &sys.mem, opts.write_mode),
            machine_balance,
        );
        r.mem.set_intensity_class(class);

        let segments = stage_segments(plan, &chunks);
        let mut seg_to_come = vec![0u32; n];
        for segs in &segments {
            for &(p, _) in segs {
                seg_to_come[p as usize] += 1;
            }
        }
        let mut send_conditions = vec![0u8; n];
        for p in 0..n {
            send_conditions[p] = match map.by_position[p] {
                ChunkMap::Remote { .. } => seg_to_come[p] as u8, // one window per segment
                ChunkMap::Dma { .. } => 2,                       // DMA reads + egress window
                ChunkMap::Local => 0,
            };
        }
        let ingress_left: Vec<u64> = (0..n)
            .map(|p| {
                if map.receives_at[p] {
                    chunks.chunk_bytes[chunks.chunk_order[p] as usize]
                        .div_ceil(sys.mem.txn_bytes)
                } else {
                    0
                }
            })
            .collect();

        let gpu = sys.gpu.clone();
        let eff = gpu.gemm_efficiency;
        let mut rank = FusedRank {
            r,
            plan: plan.clone(),
            chunks,
            map,
            dma,
            n,
            gpu,
            eff,
            compute_scale,
            dram_reads: traffic.dram_reads,
            seg_to_come,
            groups_pending: vec![0u32; n],
            send_conditions,
            local_done: vec![false; n],
            ingress_done: vec![false; n],
            ingress_scheduled: vec![false; n],
            ingress_groups: vec![GroupId::NONE; n],
            tracker_done: vec![SimTime::MAX; n],
            sent_done: vec![SimTime::MAX; n],
            ingress_left,
            sender_wgs_left: None,
            stage: 0,
            stage_compute_done: false,
            gemm_time: SimTime::ZERO,
            stage_ends: Vec::new(),
            tags: Vec::new(),
            newly_tracker_done: Vec::new(),
        };
        rank.start_stage(0);
        rank
    }

    fn chunk_bytes_at(&self, p: usize) -> u64 {
        self.chunks.chunk_bytes[self.chunks.chunk_order[p] as usize]
    }

    /// The per-stage plan segments this rank writes (for diagnostics).
    pub fn segments(&self) -> Vec<Vec<(u32, u64)>> {
        stage_segments(&self.plan, &self.chunks)
    }

    /// Record this rank's timeline (`t3::trace`): CU stage compute, DRAM
    /// service lanes, link egress/ingress windows, tracker completions and
    /// trigger firings. Purely observational — traced runs are
    /// bit-identical to untraced ones.
    pub fn enable_trace(&mut self, rank: u64) {
        self.r.enable_trace(rank);
    }

    /// [`FusedRank::enable_trace`] with an explicit sink mode.
    pub fn enable_trace_with(&mut self, rank: u64, mode: SinkMode) {
        self.r.enable_trace_with(rank, mode);
    }

    /// Rebind this rank's egress (fabric integration). Must be called
    /// before the first event is processed.
    pub fn attach_port(&mut self, port: crate::fabric::EgressPort) {
        debug_assert!(port.bytes_carried() == 0, "attach_port expects a fresh port");
        self.r.link_out = port;
    }

    fn start_stage(&mut self, s: u64) {
        let bytes = stage_reads(&self.plan, self.dram_reads, s).max(self.r.sys.mem.txn_bytes);
        self.r.submit_tagged(
            bytes,
            TxnKind::Read,
            Stream::Compute,
            TrafficClass::GemmRead,
            GroupTag::StageReads(s),
        );
    }

    /// Time of this rank's next pending event.
    pub fn next_time(&self) -> Option<SimTime> {
        self.r.q.peek_time()
    }

    /// Process one event; outbound messages for the downstream neighbor
    /// are appended to `out`. Returns `false` when the calendar is empty.
    pub fn step(&mut self, out: &mut Vec<FusedMsg>) -> bool {
        let Some((t, ev)) = self.r.next_event() else {
            return false;
        };
        let mut tags = std::mem::take(&mut self.tags);
        self.r.drain_tags(&mut tags);
        for (tag, blocked) in tags.drain(..) {
            match tag {
                GroupTag::StageReads(s) if s == self.stage => {
                    let ct = self
                        .plan
                        .stage_compute_time(s, &self.gpu, self.gpu.cu_count, self.eff);
                    let ct = if self.compute_scale != 1.0 {
                        ct * self.compute_scale
                    } else {
                        ct
                    };
                    let stall = blocked * self.gpu.stall_unhidden;
                    self.r.sink.span(Lane::CuCompute, t, t + ct + stall, 0, SpanLabel::Stage(s));
                    self.r.q.schedule_in(ct + stall, Ev::StageCompute(s));
                }
                GroupTag::ChunkLocal(p) => {
                    let p = p as usize;
                    self.groups_pending[p] -= 1;
                    if self.groups_pending[p] == 0
                        && self.seg_to_come[p] == 0
                        && !self.local_done[p]
                    {
                        self.local_done[p] = true;
                        if check_tracker(p, &self.map, &self.local_done, &self.ingress_done) {
                            self.tracker_done[p] = t;
                            self.r
                                .sink
                                .instant(Lane::Tracker, t, InstantKind::TrackerDone(p as u32));
                            self.newly_tracker_done.push(p);
                        }
                    }
                }
                GroupTag::ChunkIngress(p) => {
                    let p = p as usize;
                    self.ingress_done[p] = true;
                    if check_tracker(p, &self.map, &self.local_done, &self.ingress_done)
                        && self.tracker_done[p] == SimTime::MAX
                    {
                        self.tracker_done[p] = t;
                        self.r.sink.instant(Lane::Tracker, t, InstantKind::TrackerDone(p as u32));
                        self.newly_tracker_done.push(p);
                    }
                }
                GroupTag::DmaReads(p) => {
                    let p = p as usize;
                    self.send_conditions[p] -= 1;
                    if self.send_conditions[p] == 0 {
                        self.sent_done[p] = t;
                    }
                }
                _ => {}
            }
        }
        self.tags = tags;

        match ev {
            Ev::StageCompute(s) if s == self.stage => self.stage_compute_done = true,
            Ev::EgressDone { pos } => {
                let p = pos as usize;
                self.send_conditions[p] -= 1;
                if self.send_conditions[p] == 0 {
                    self.sent_done[p] = t;
                    if matches!(self.map.by_position[p], ChunkMap::Remote { .. }) {
                        // Remote-mapped chunk: "local" completion is the
                        // egress of its fine-grained stores (nothing lands
                        // in local DRAM).
                        self.local_done[p] = true;
                        self.tracker_done[p] = t;
                        self.r.sink.instant(Lane::Tracker, t, InstantKind::TrackerDone(p as u32));
                    }
                }
            }
            Ev::Ingress { pos, n: cnt } => {
                let p = pos as usize;
                debug_assert!(self.ingress_scheduled[p]);
                let txn = Txn {
                    kind: TxnKind::NmcUpdate,
                    stream: Stream::Comm,
                    class: TrafficClass::RsWrite,
                    group: self.ingress_groups[p],
                };
                self.r.mem.submit_burst(cnt as u64, txn, &mut self.r.q);
            }
            _ => {}
        }

        // Stage retirement.
        if self.stage_compute_done {
            let segs = self.segments_of(self.stage);
            for &(p, wgs) in &segs {
                let p = p as usize;
                let bytes = wgs * self.plan.wg_out_bytes();
                match self.map.by_position[p] {
                    ChunkMap::Remote { .. } => {
                        // Fine-grained remote stores: straight to the link.
                        let w = self.r.egress(t, bytes, SpanLabel::Chunk(p as u32));
                        self.r.q.schedule(w.done, Ev::EgressDone { pos: p as u32 });
                        self.seg_to_come[p] -= 1;
                        // The downstream neighbor paces the matching
                        // ingress across this segment's window (+ hop
                        // latency). In the loopback mirror that neighbor
                        // is ourselves.
                        let nxt = p + 1;
                        if nxt < self.n {
                            out.push(FusedMsg::Segment {
                                pos: nxt as u32,
                                wgs,
                                of_total: self.chunks.chunk_wgs
                                    [self.chunks.chunk_order[0] as usize],
                                start: w.arrive_first,
                                end: w.arrive_last,
                            });
                        }
                    }
                    _ => {
                        // Local NMC updates through the compute stream.
                        self.r.submit_tagged(
                            bytes,
                            TxnKind::NmcUpdate,
                            Stream::Compute,
                            TrafficClass::GemmWrite,
                            GroupTag::ChunkLocal(p as u32),
                        );
                        self.groups_pending[p] += 1;
                        self.seg_to_come[p] -= 1;
                    }
                }
            }
            self.stage_ends.push(t);
            self.stage += 1;
            self.stage_compute_done = false;
            if self.stage < self.plan.num_stages {
                self.start_stage(self.stage);
            } else {
                self.gemm_time = t;
            }
        }

        // Tracker fired ⇒ mark DMA ready and launch it (positions 1..N-2).
        // The downstream neighbor receives the chunk across the egress
        // window shifted by the hop latency — receive of chunk p+1 overlaps
        // our send of chunk p, as in Figure 7's steady state.
        let mut fired = std::mem::take(&mut self.newly_tracker_done);
        for p in fired.drain(..) {
            if let ChunkMap::Dma { .. } = self.map.by_position[p] {
                self.dma.mark_ready(p).expect("dma entry");
                self.r.sink.instant(Lane::Tracker, t, InstantKind::Trigger(p as u32));
                // Tracker completion → DMA trigger: the causal edge the
                // critical-path walker follows through the trigger.
                self.r.note_local_edge(DepKind::Trigger, self.tracker_done[p], t);
                let bytes = self.chunk_bytes_at(p);
                // DMA reads the (partially reduced) chunk via the comm
                // stream; egress window in parallel (pipelined).
                self.r.submit_tagged(
                    bytes,
                    TxnKind::Read,
                    Stream::Comm,
                    TrafficClass::RsRead,
                    GroupTag::DmaReads(p as u32),
                );
                let w = self.r.egress(t, bytes, SpanLabel::Chunk(p as u32));
                self.r.q.schedule(w.done, Ev::EgressDone { pos: p as u32 });
                let nxt = p + 1;
                if nxt < self.n {
                    out.push(FusedMsg::Dma {
                        pos: nxt as u32,
                        start: w.arrive_first,
                        end: w.arrive_last,
                    });
                }
            }
        }
        self.newly_tracker_done = fired;
        true
    }

    fn segments_of(&self, stage: u64) -> Vec<(u32, u64)> {
        // Recomputing one stage's segments is cheap (few entries) and keeps
        // the struct free of a borrowed-while-mutated segments field.
        stage_segments(&self.plan, &self.chunks)[stage as usize].clone()
    }

    /// Apply an upstream neighbor's ingress-window message.
    pub fn deliver(&mut self, msg: &FusedMsg) {
        let p = msg.pos() as usize;
        if p >= self.n || !self.map.receives_at[p] || self.ingress_left[p] == 0 {
            return;
        }
        match *msg {
            FusedMsg::Segment {
                pos,
                wgs,
                of_total,
                start,
                end,
            } => {
                if self.ingress_groups[p] == GroupId::NONE {
                    self.ingress_groups[p] = self
                        .r
                        .register_group(self.ingress_left[p], GroupTag::ChunkIngress(pos));
                    self.ingress_scheduled[p] = true;
                }
                let left = self.sender_wgs_left.get_or_insert(of_total);
                *left -= wgs;
                // Pace a proportional share of the chunk's ingress across
                // this segment's window; the final segment flushes the
                // remainder.
                let part = if *left == 0 {
                    self.ingress_left[p]
                } else {
                    (self.ingress_left[p] * wgs / (*left + wgs)).min(self.ingress_left[p])
                };
                if part > 0 {
                    self.ingress_left[p] -= part;
                    let bytes = part * self.r.mem.txn_bytes();
                    self.r.sink.span(Lane::LinkIngress, start, end, bytes, SpanLabel::Chunk(pos));
                    self.r.schedule_ingress_window(pos, part, start, end, PACE_BATCH);
                }
            }
            FusedMsg::Dma { pos, start, end } => {
                debug_assert!(!self.ingress_scheduled[p]);
                self.ingress_scheduled[p] = true;
                let txns = self.ingress_left[p];
                self.ingress_left[p] = 0;
                self.ingress_groups[p] =
                    self.r.register_group(txns, GroupTag::ChunkIngress(pos));
                let bytes = txns * self.r.mem.txn_bytes();
                self.r.sink.span(Lane::LinkIngress, start, end, bytes, SpanLabel::Chunk(pos));
                self.r.schedule_ingress_window(pos, txns, start, end, PACE_BATCH);
            }
        }
    }

    /// Consume the drained rank into its result.
    pub fn into_result(mut self) -> FusedResult {
        debug_assert!(self.r.mem.idle());
        debug_assert!(self.dma.all_fired(), "not all DMA entries fired");
        debug_assert!(self.local_done.iter().all(|&d| d));
        let total = self.r.now();
        // Peak tracker footprint: WF tiles of the stages in flight — bounded
        // by one stage's WFs plus the incoming chunk's tiles.
        let tracker_peak_tiles = self.plan.stage_wgs * self.plan.tiling.wfs_per_wg()
            + self.chunks.chunk_wf_tiles.iter().max().copied().unwrap_or(0);
        let timeline = self.r.take_timeline(total);
        let link_bytes = self.r.link_out.bytes_carried();
        let mut mem = self.r.mem;
        FusedResult {
            total,
            gemm_time: self.gemm_time,
            stage_ends: self.stage_ends,
            tracker_done: self.tracker_done,
            sent_done: self.sent_done,
            counters: mem.counters,
            tracker_peak_tiles,
            trace: mem.trace.take(),
            timeline,
            link_bytes,
        }
    }
}

/// Run the fused GEMM + ring-RS on device 0 of `devices`, mirroring the
/// homogeneous neighbors (§5.1.1): the rank's outbound ring messages are
/// delivered back to itself. The multi-rank cluster engine
/// ([`crate::cluster`]) reproduces this bit-for-bit in its uniform
/// configuration.
pub fn run_fused_gemm_rs(
    sys: &SystemConfig,
    plan: &StagePlan,
    devices: u64,
    opts: &FusedOpts,
) -> FusedResult {
    run_fused_gemm_rs_opt(sys, plan, devices, opts, false)
}

/// [`run_fused_gemm_rs`] with timeline tracing enabled; the result's
/// `timeline` carries the rank-0 trace. Every simulated quantity is
/// bit-identical to the untraced run.
#[deprecated(
    since = "0.2.0",
    note = "trace capture is an ExecOpts field now: run a FusedGemmRs phase \
            through cluster::execute, or run_collective(traced = true)"
)]
pub fn run_fused_gemm_rs_traced(
    sys: &SystemConfig,
    plan: &StagePlan,
    devices: u64,
    opts: &FusedOpts,
) -> FusedResult {
    run_fused_gemm_rs_opt(sys, plan, devices, opts, true)
}

fn run_fused_gemm_rs_opt(
    sys: &SystemConfig,
    plan: &StagePlan,
    devices: u64,
    opts: &FusedOpts,
    traced: bool,
) -> FusedResult {
    let mut rank = FusedRank::new(sys, plan, devices, 0, opts, 1.0, sys.link.clone());
    if traced {
        rank.enable_trace(0);
    }
    let mut msgs = Vec::new();
    while rank.step(&mut msgs) {
        for m in msgs.drain(..) {
            rank.deliver(&m);
        }
    }
    rank.into_result()
}

fn check_tracker(p: usize, map: &OutputMap, local: &[bool], ingress: &[bool]) -> bool {
    local[p] && (!map.receives_at[p] || ingress[p])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DType, SystemConfig};
    use crate::engine::collective_run::{run_ag_baseline, run_rs_baseline};
    use crate::engine::gemm_run::run_gemm;
    use crate::gemm::{GemmShape, Tiling};

    fn plan(m: u64, n: u64, k: u64) -> StagePlan {
        StagePlan::new(
            GemmShape::new(m, n, k, DType::F16),
            Tiling::default(),
            &SystemConfig::table1().gpu,
        )
    }

    fn opts(policy: ArbPolicy) -> FusedOpts {
        FusedOpts {
            policy,
            ..FusedOpts::default()
        }
    }

    #[test]
    fn stage_segments_cover_all_wgs() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let c = ChunkPlan::new(&p, 8, 0);
        let segs = stage_segments(&p, &c);
        assert_eq!(segs.len(), p.num_stages as usize);
        let total: u64 = segs.iter().flatten().map(|&(_, w)| w).sum();
        assert_eq!(total, p.total_wgs);
        // Per position, totals match the chunk sizes.
        let mut per_pos = vec![0u64; 8];
        for &(pos, w) in segs.iter().flatten() {
            per_pos[pos as usize] += w;
        }
        for pos in 0..8usize {
            assert_eq!(per_pos[pos], c.chunk_wgs[c.chunk_order[pos] as usize]);
        }
        let _ = sys;
    }

    #[test]
    fn fused_faster_than_sequential() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128); // T-NLG FC-2 TP=8
        let devices = 8;
        let g = run_gemm(&sys, &p, 80, crate::gemm::traffic::WriteMode::ThroughLlc);
        let rs = run_rs_baseline(&sys, p.shape.out_bytes(), devices, 80);
        let sequential = g.time + rs.time;
        let fused = run_fused_gemm_rs(&sys, &p, devices, &opts(ArbPolicy::T3Mca));
        assert!(
            fused.total < sequential,
            "fused {} !< sequential {}",
            fused.total,
            sequential
        );
        // ...but not faster than the ideal overlap (max of isolated parts).
        let ideal = g.time.max(rs.time);
        assert!(
            fused.total.as_ps() as f64 >= ideal.as_ps() as f64 * 0.95,
            "fused {} below ideal {}",
            fused.total,
            ideal
        );
    }

    #[test]
    fn mca_beats_roundrobin() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let rr = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::RoundRobin));
        let mca = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::T3Mca));
        assert!(
            mca.total <= rr.total,
            "MCA {} vs RR {}",
            mca.total,
            rr.total
        );
    }

    #[test]
    fn tracker_condition_ordering() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 4096, 1024);
        let res = run_fused_gemm_rs(&sys, &p, 4, &opts(ArbPolicy::T3Mca));
        // All positions completed, in increasing time order (ring chain).
        for pos in 1..4 {
            assert!(res.tracker_done[pos] < SimTime::MAX);
            if pos >= 2 {
                assert!(
                    res.tracker_done[pos] > res.tracker_done[pos - 1],
                    "tracker order violated at {pos}"
                );
            }
        }
        assert!(res.total >= res.tracker_done[3]);
        assert!(res.gemm_time > SimTime::ZERO);
    }

    #[test]
    fn fused_traffic_less_than_sequential() {
        // §6.2: fusion + NMC reduce DRAM traffic.
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let g = run_gemm(&sys, &p, 80, crate::gemm::traffic::WriteMode::ThroughLlc);
        let rs = run_rs_baseline(&sys, p.shape.out_bytes(), 8, 80);
        let seq_total = g.counters.total() + rs.counters.total();
        let fused = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::T3Mca));
        let fused_ag_free = fused.counters.total();
        assert!(
            (fused_ag_free as f64) < seq_total as f64 * 0.9,
            "fused {} vs sequential {}",
            fused_ag_free,
            seq_total
        );
        let _ = run_ag_baseline(&sys, p.shape.out_bytes(), 8, 80);
    }

    #[test]
    fn rs_reads_reduced_vs_baseline() {
        // §6.2: RS reads shrink ~2.4x (first step read eliminated by
        // fusion, partial-copy reads eliminated by NMC).
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let rs = run_rs_baseline(&sys, p.shape.out_bytes(), 8, 80);
        let fused = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::T3Mca));
        let ratio = rs.counters.rs_reads as f64 / fused.counters.rs_reads as f64;
        assert!((1.8..3.0).contains(&ratio), "RS read reduction {ratio}");
    }

    #[test]
    fn works_for_various_device_counts() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 2048, 512);
        for devices in [2u64, 3, 4, 8, 16] {
            let res = run_fused_gemm_rs(&sys, &p, devices, &opts(ArbPolicy::T3Mca));
            assert!(res.total > SimTime::ZERO, "devices={devices}");
            assert_eq!(res.tracker_done.len(), devices as usize);
        }
    }

    #[test]
    fn rank_machine_runs_for_any_rank_id() {
        // Every rank's loopback mirror drains cleanly (per-rank chunk
        // orders differ, the machine must not assume rank 0).
        let sys = SystemConfig::table1();
        let p = plan(4096, 2048, 512);
        for rank in 0..4u64 {
            let mut r =
                FusedRank::new(&sys, &p, 4, rank, &opts(ArbPolicy::T3Mca), 1.0, sys.link.clone());
            let mut msgs = Vec::new();
            while r.step(&mut msgs) {
                for m in msgs.drain(..) {
                    r.deliver(&m);
                }
            }
            let res = r.into_result();
            assert!(res.total > SimTime::ZERO, "rank={rank}");
        }
    }

    #[test]
    fn stage_ends_are_monotone_and_finish_at_gemm_time() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let res = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::T3Mca));
        assert_eq!(res.stage_ends.len(), p.num_stages as usize);
        assert!(res.stage_ends.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*res.stage_ends.last().unwrap(), res.gemm_time);
    }

    #[test]
    fn compute_scale_slows_the_gemm() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 2048, 512);
        let run = |scale: f64| {
            let mut r =
                FusedRank::new(&sys, &p, 4, 0, &opts(ArbPolicy::T3Mca), scale, sys.link.clone());
            let mut msgs = Vec::new();
            while r.step(&mut msgs) {
                for m in msgs.drain(..) {
                    r.deliver(&m);
                }
            }
            r.into_result()
        };
        let nominal = run(1.0);
        let slow = run(1.5);
        assert!(slow.gemm_time > nominal.gemm_time);
        assert!(slow.total > nominal.total);
        // The plain entry point is exactly the scale-1.0 loopback.
        let plain = run_fused_gemm_rs(&sys, &p, 4, &opts(ArbPolicy::T3Mca));
        assert_eq!(plain.total, nominal.total);
        assert_eq!(plain.tracker_done, nominal.tracker_done);
    }
}
