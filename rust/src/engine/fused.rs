//! The T3 fused GEMM + ring reduce-scatter engine (Section 4, Figure 7-8).
//!
//! One device's timeline, with neighbor traffic mirrored (homogeneous
//! devices, staggered WG scheduling):
//!
//! * The GEMM executes stage by stage, its WGs reordered chunk-first by the
//!   staggered `ChunkPlan`. Stage reads flow through the MC *compute*
//!   stream; stage writes land according to the `OutputMap`:
//!   - position 0 (remote-mapped): fine-grained stores straight onto the
//!     egress link (no local DRAM traffic — §6.2's "fusion eliminates local
//!     writes from GEMM's first stage");
//!   - other positions: local near-memory op-and-store updates.
//! * Incoming DMA updates for position `p` mirror our own egress of
//!   position `p-1` (+ link latency), entering the MC *comm* stream as NMC
//!   updates.
//! * When a position's local updates AND incoming updates have all landed
//!   (the Tracker condition — threshold = 2 updates/element for ring-RS),
//!   the pre-programmed DMA fires: chunk reads on the comm stream + an
//!   egress window; its completion triggers the next position's ingress.
//! * The final position is the device's fully-reduced chunk; the run ends
//!   when it is reduced and all egress/ingress traffic has drained.
//!
//! Contention between the GEMM's reads and the RS's bursty updates/reads is
//! resolved by the configured `ArbPolicy` — `RoundRobin` reproduces the
//! paper's T3 configuration, `T3Mca` adds the §4.5 arbitration policy.

use crate::addrspace::{ChunkMap, DmaTable, OutputMap};
use crate::config::{ArbPolicy, SystemConfig};
use crate::gemm::traffic::{gemm_bytes_per_flop, gemm_traffic, stage_reads, WriteMode};
use crate::gemm::{ChunkPlan, StagePlan};
use crate::hw::hbm::{TrafficClass, Txn, TxnKind};
use crate::hw::mc::{intensity_class, Stream};
use crate::sim::stats::DramCounters;
use crate::sim::time::SimTime;

use super::{Ev, GroupTag, Runner, PACE_BATCH};

/// Result of a fused GEMM-RS run.
#[derive(Debug, Clone)]
pub struct FusedResult {
    /// End-to-end fused time (GEMM + RS fully overlapped + drain).
    pub total: SimTime,
    /// When the GEMM's last stage retired (to quantify GEMM slowdown
    /// under contention, Figure 17).
    pub gemm_time: SimTime,
    /// Tracker-completion time per position.
    pub tracker_done: Vec<SimTime>,
    pub counters: DramCounters,
    /// Peak concurrently-live tracker WF-tiles (hardware budget check).
    pub tracker_peak_tiles: u64,
    /// Figure-17 traffic trace (when `FusedOpts::trace_bin` is set).
    pub trace: Option<crate::hw::hbm::TrafficTrace>,
}

/// Options for a fused run.
#[derive(Debug, Clone)]
pub struct FusedOpts {
    pub policy: ArbPolicy,
    /// Producer write mode for the GEMM's local (non-remote) stores. T3's
    /// default is the uncached NMC bypass (§4.3); `ThroughLlc` models a
    /// fused producer whose writes still allocate, isolating the overlap
    /// benefit from the cache benefit.
    pub write_mode: WriteMode,
    /// Record a Figure-17 traffic trace with this bin size.
    pub trace_bin: Option<SimTime>,
}

impl Default for FusedOpts {
    fn default() -> Self {
        FusedOpts {
            policy: ArbPolicy::T3Mca,
            write_mode: WriteMode::BypassLlc,
            trace_bin: None,
        }
    }
}

/// Per-stage write segments: (position, wg count).
fn stage_segments(plan: &StagePlan, chunks: &ChunkPlan) -> Vec<Vec<(u32, u64)>> {
    let n = chunks.devices as usize;
    // WG count processed per position, in processing order.
    let pos_wgs: Vec<u64> = (0..n)
        .map(|p| chunks.chunk_wgs[chunks.chunk_order[p] as usize])
        .collect();
    let mut segments = vec![Vec::new(); plan.num_stages as usize];
    let mut pos = 0usize;
    let mut left_in_pos = pos_wgs[0];
    for (s, seg) in segments.iter_mut().enumerate() {
        let mut left_in_stage = plan.wgs_in_stage(s as u64);
        while left_in_stage > 0 {
            let take = left_in_stage.min(left_in_pos);
            seg.push((pos as u32, take));
            left_in_stage -= take;
            left_in_pos -= take;
            if left_in_pos == 0 && pos + 1 < n {
                pos += 1;
                left_in_pos = pos_wgs[pos];
            }
        }
    }
    segments
}

/// Run the fused GEMM + ring-RS on device 0 of `devices`.
pub fn run_fused_gemm_rs(
    sys: &SystemConfig,
    plan: &StagePlan,
    devices: u64,
    opts: &FusedOpts,
) -> FusedResult {
    let chunks = ChunkPlan::new(plan, devices, 0);
    let map = OutputMap::ring_reduce_scatter(&chunks, 0);
    let mut dma = DmaTable::program(&map, &chunks);
    let n = devices as usize;
    let segments = stage_segments(plan, &chunks);
    let traffic = gemm_traffic(plan, &sys.mem, opts.write_mode);

    let mut r = Runner::new(sys, opts.policy);
    if let Some(bin) = opts.trace_bin {
        r.mem.trace = Some(crate::hw::hbm::TrafficTrace::new(bin));
    }
    // MCA threshold class from the producer's memory intensity (§6.1.3).
    let machine_balance = sys.mem.total_bw_gbps * 1e9 / sys.gpu.sustained_gemm_flops(plan.shape.dtype);
    let class = intensity_class(
        gemm_bytes_per_flop(plan, &sys.mem, opts.write_mode),
        machine_balance,
    );
    r.mem.set_intensity_class(class);

    // ---- per-position bookkeeping ----
    let mut seg_to_come = vec![0u32; n]; // write segments not yet submitted
    for segs in &segments {
        for &(p, _) in segs {
            seg_to_come[p as usize] += 1;
        }
    }
    let mut groups_pending = vec![0u32; n]; // submitted, not yet landed
    let mut send_conditions = vec![0u8; n]; // egress windows + DMA reads
    for p in 0..n {
        send_conditions[p] = match map.by_position[p] {
            ChunkMap::Remote { .. } => seg_to_come[p] as u8, // one window per segment
            ChunkMap::Dma { .. } => 2,                       // DMA reads + egress window
            ChunkMap::Local => 0,
        };
    }
    let mut local_done = vec![false; n];
    let mut ingress_done = vec![false; n];
    let mut ingress_scheduled = vec![false; n];
    let mut ingress_groups = vec![crate::hw::hbm::GroupId::NONE; n];
    let mut tracker_done = vec![SimTime::MAX; n];
    let mut sent_done = vec![SimTime::MAX; n];

    let chunk_bytes_at = |p: usize| chunks.chunk_bytes[chunks.chunk_order[p] as usize];

    // ---- GEMM stage machine ----
    // Read phase drains, then the compute phase retires (see gemm_run.rs:
    // this coupling is how RS burstiness slows the producer, Fig 17b).
    let mut stage = 0u64;
    let mut stage_compute_done = false;
    let gpu = sys.gpu.clone();
    let eff = gpu.gemm_efficiency;
    let start_stage = |r: &mut Runner, s: u64| {
        let bytes = stage_reads(plan, traffic.dram_reads, s).max(r.sys.mem.txn_bytes);
        r.submit_tagged(
            bytes,
            TxnKind::Read,
            Stream::Compute,
            TrafficClass::GemmRead,
            GroupTag::StageReads(s),
        );
    };
    start_stage(&mut r, 0);

    let mut gemm_time = SimTime::ZERO;
    let mut tags = Vec::new();
    // Deferred actions to avoid re-entrancy: positions whose tracker
    // condition completed this event.
    let mut newly_tracker_done: Vec<usize> = Vec::new();
    // Ingress transactions still to mirror per receiving position.
    let mut ingress_left: Vec<u64> = (0..n)
        .map(|p| {
            if map.receives_at[p] {
                chunk_bytes_at(p).div_ceil(sys.mem.txn_bytes)
            } else {
                0
            }
        })
        .collect();
    let mut pos0_wgs_left = chunks.chunk_wgs[chunks.chunk_order[0] as usize];

    while let Some((t, ev)) = r.next_event() {
        r.drain_tags(&mut tags);
        for (tag, blocked) in tags.drain(..) {
            match tag {
                GroupTag::StageReads(s) if s == stage => {
                    let ct = plan.stage_compute_time(s, &gpu, gpu.cu_count, eff);
                    let stall = blocked * gpu.stall_unhidden;
                    r.q.schedule_in(ct + stall, Ev::StageCompute(s));
                }
                GroupTag::ChunkLocal(p) => {
                    let p = p as usize;
                    groups_pending[p] -= 1;
                    if groups_pending[p] == 0 && seg_to_come[p] == 0 && !local_done[p] {
                        local_done[p] = true;
                        if check_tracker(p, &map, &local_done, &ingress_done) {
                            tracker_done[p] = t;
                            newly_tracker_done.push(p);
                        }
                    }
                }
                GroupTag::ChunkIngress(p) => {
                    let p = p as usize;
                    ingress_done[p] = true;
                    if check_tracker(p, &map, &local_done, &ingress_done) && tracker_done[p] == SimTime::MAX {
                        tracker_done[p] = t;
                        newly_tracker_done.push(p);
                    }
                }
                GroupTag::DmaReads(p) => {
                    let p = p as usize;
                    send_conditions[p] -= 1;
                    if send_conditions[p] == 0 {
                        sent_done[p] = t;
                    }
                }
                _ => {}
            }
        }
        match ev {
            Ev::StageCompute(s) if s == stage => stage_compute_done = true,
            Ev::EgressDone { pos } => {
                let p = pos as usize;
                send_conditions[p] -= 1;
                if send_conditions[p] == 0 {
                    sent_done[p] = t;
                    if matches!(map.by_position[p], ChunkMap::Remote { .. }) {
                        // Remote-mapped chunk: "local" completion is the
                        // egress of its fine-grained stores (nothing lands
                        // in local DRAM).
                        local_done[p] = true;
                        tracker_done[p] = t;
                    }
                }
            }
            Ev::Ingress { pos, n: cnt } => {
                let p = pos as usize;
                debug_assert!(ingress_scheduled[p]);
                let txn = Txn {
                    kind: TxnKind::NmcUpdate,
                    stream: Stream::Comm,
                    class: TrafficClass::RsWrite,
                    group: ingress_groups[p],
                };
                r.mem.submit_burst(cnt as u64, txn, &mut r.q);
            }
            _ => {}
        }

        // Stage retirement.
        if stage_compute_done {
            for &(p, wgs) in &segments[stage as usize] {
                let p = p as usize;
                let bytes = wgs * plan.wg_out_bytes();
                match map.by_position[p] {
                    ChunkMap::Remote { .. } => {
                        // Fine-grained remote stores: straight to the link.
                        let w = r.link_out.reserve(t, bytes);
                        r.q.schedule(w.done, Ev::EgressDone { pos: p as u32 });
                        seg_to_come[p] -= 1;
                        // Mirror: the upstream neighbor remote-stores its
                        // first chunk (= our position p+1's chunk, by the
                        // stagger) on the same schedule. Pace a
                        // proportional share of that ingress across this
                        // segment's window (+ link latency).
                        let nxt = p + 1;
                        if nxt < n && map.receives_at[nxt] && ingress_left[nxt] > 0 {
                            if ingress_groups[nxt] == crate::hw::hbm::GroupId::NONE {
                                ingress_groups[nxt] = r.register_group(
                                    ingress_left[nxt],
                                    GroupTag::ChunkIngress(nxt as u32),
                                );
                                ingress_scheduled[nxt] = true;
                            }
                            pos0_wgs_left -= wgs;
                            let part = if pos0_wgs_left == 0 {
                                ingress_left[nxt]
                            } else {
                                (ingress_left[nxt] * wgs
                                    / (pos0_wgs_left + wgs))
                                    .min(ingress_left[nxt])
                            };
                            if part > 0 {
                                ingress_left[nxt] -= part;
                                let lat = r.sys.link.latency;
                                r.schedule_ingress_window(
                                    nxt as u32,
                                    part,
                                    w.start + lat,
                                    w.done + lat,
                                    PACE_BATCH,
                                );
                            }
                        }
                    }
                    _ => {
                        // Local NMC updates through the compute stream.
                        r.submit_tagged(
                            bytes,
                            TxnKind::NmcUpdate,
                            Stream::Compute,
                            TrafficClass::GemmWrite,
                            GroupTag::ChunkLocal(p as u32),
                        );
                        groups_pending[p] += 1;
                        seg_to_come[p] -= 1;
                    }
                }
            }
            stage += 1;
            stage_compute_done = false;
            if stage < plan.num_stages {
                start_stage(&mut r, stage);
            } else {
                gemm_time = t;
            }
        }

        // Tracker fired ⇒ mark DMA ready and launch it (positions 1..N-2).
        // The upstream neighbor triggers its corresponding DMA at the same
        // (mirrored) moment, so the next position's ingress is paced over
        // the same window shifted by the link latency — receive of chunk
        // p+1 overlaps our send of chunk p, as in Figure 7's steady state.
        for p in newly_tracker_done.drain(..) {
            if let ChunkMap::Dma { .. } = map.by_position[p] {
                dma.mark_ready(p).expect("dma entry");
                let bytes = chunk_bytes_at(p);
                // DMA reads the (partially reduced) chunk via the comm
                // stream; egress window in parallel (pipelined).
                r.submit_tagged(
                    bytes,
                    TxnKind::Read,
                    Stream::Comm,
                    TrafficClass::RsRead,
                    GroupTag::DmaReads(p as u32),
                );
                let w = r.link_out.reserve(t, bytes);
                r.q.schedule(w.done, Ev::EgressDone { pos: p as u32 });
                let nxt = p + 1;
                if nxt < n && map.receives_at[nxt] && ingress_left[nxt] > 0 {
                    debug_assert!(!ingress_scheduled[nxt]);
                    ingress_scheduled[nxt] = true;
                    let txns = ingress_left[nxt];
                    ingress_left[nxt] = 0;
                    ingress_groups[nxt] =
                        r.register_group(txns, GroupTag::ChunkIngress(nxt as u32));
                    let lat = r.sys.link.latency;
                    r.schedule_ingress_window(
                        nxt as u32,
                        txns,
                        w.start + lat,
                        w.done + lat,
                        PACE_BATCH,
                    );
                }
            }
        }
    }

    debug_assert!(r.mem.idle());
    debug_assert!(dma.all_fired(), "not all DMA entries fired");
    debug_assert!(local_done.iter().all(|&d| d));
    let total = r.now();
    // Peak tracker footprint: WF tiles of the stages in flight — bounded by
    // one stage's WFs plus the incoming chunk's tiles.
    let tracker_peak_tiles = plan.stage_wgs * plan.tiling.wfs_per_wg()
        + chunks.chunk_wf_tiles.iter().max().copied().unwrap_or(0);

    FusedResult {
        total,
        gemm_time,
        tracker_done,
        counters: r.mem.counters,
        tracker_peak_tiles,
        trace: r.mem.trace.take(),
    }
}

fn check_tracker(p: usize, map: &OutputMap, local: &[bool], ingress: &[bool]) -> bool {
    local[p] && (!map.receives_at[p] || ingress[p])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DType, SystemConfig};
    use crate::engine::collective_run::{run_ag_baseline, run_rs_baseline};
    use crate::engine::gemm_run::run_gemm;
    use crate::gemm::{GemmShape, Tiling};

    fn plan(m: u64, n: u64, k: u64) -> StagePlan {
        StagePlan::new(
            GemmShape::new(m, n, k, DType::F16),
            Tiling::default(),
            &SystemConfig::table1().gpu,
        )
    }

    fn opts(policy: ArbPolicy) -> FusedOpts {
        FusedOpts {
            policy,
            ..FusedOpts::default()
        }
    }

    #[test]
    fn stage_segments_cover_all_wgs() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let c = ChunkPlan::new(&p, 8, 0);
        let segs = stage_segments(&p, &c);
        assert_eq!(segs.len(), p.num_stages as usize);
        let total: u64 = segs.iter().flatten().map(|&(_, w)| w).sum();
        assert_eq!(total, p.total_wgs);
        // Per position, totals match the chunk sizes.
        let mut per_pos = vec![0u64; 8];
        for &(pos, w) in segs.iter().flatten() {
            per_pos[pos as usize] += w;
        }
        for pos in 0..8usize {
            assert_eq!(per_pos[pos], c.chunk_wgs[c.chunk_order[pos] as usize]);
        }
        let _ = sys;
    }

    #[test]
    fn fused_faster_than_sequential() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128); // T-NLG FC-2 TP=8
        let devices = 8;
        let g = run_gemm(&sys, &p, 80, crate::gemm::traffic::WriteMode::ThroughLlc);
        let rs = run_rs_baseline(&sys, p.shape.out_bytes(), devices, 80);
        let sequential = g.time + rs.time;
        let fused = run_fused_gemm_rs(&sys, &p, devices, &opts(ArbPolicy::T3Mca));
        assert!(
            fused.total < sequential,
            "fused {} !< sequential {}",
            fused.total,
            sequential
        );
        // ...but not faster than the ideal overlap (max of isolated parts).
        let ideal = g.time.max(rs.time);
        assert!(
            fused.total.as_ps() as f64 >= ideal.as_ps() as f64 * 0.95,
            "fused {} below ideal {}",
            fused.total,
            ideal
        );
    }

    #[test]
    fn mca_beats_roundrobin() {
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let rr = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::RoundRobin));
        let mca = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::T3Mca));
        assert!(
            mca.total <= rr.total,
            "MCA {} vs RR {}",
            mca.total,
            rr.total
        );
    }

    #[test]
    fn tracker_condition_ordering() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 4096, 1024);
        let res = run_fused_gemm_rs(&sys, &p, 4, &opts(ArbPolicy::T3Mca));
        // All positions completed, in increasing time order (ring chain).
        for pos in 1..4 {
            assert!(res.tracker_done[pos] < SimTime::MAX);
            if pos >= 2 {
                assert!(
                    res.tracker_done[pos] > res.tracker_done[pos - 1],
                    "tracker order violated at {pos}"
                );
            }
        }
        assert!(res.total >= res.tracker_done[3]);
        assert!(res.gemm_time > SimTime::ZERO);
    }

    #[test]
    fn fused_traffic_less_than_sequential() {
        // §6.2: fusion + NMC reduce DRAM traffic.
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let g = run_gemm(&sys, &p, 80, crate::gemm::traffic::WriteMode::ThroughLlc);
        let rs = run_rs_baseline(&sys, p.shape.out_bytes(), 8, 80);
        let seq_total = g.counters.total() + rs.counters.total();
        let fused = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::T3Mca));
        let fused_ag_free = fused.counters.total();
        assert!(
            (fused_ag_free as f64) < seq_total as f64 * 0.9,
            "fused {} vs sequential {}",
            fused_ag_free,
            seq_total
        );
        let _ = run_ag_baseline(&sys, p.shape.out_bytes(), 8, 80);
    }

    #[test]
    fn rs_reads_reduced_vs_baseline() {
        // §6.2: RS reads shrink ~2.4x (first step read eliminated by
        // fusion, partial-copy reads eliminated by NMC).
        let sys = SystemConfig::table1();
        let p = plan(8192, 4256, 2128);
        let rs = run_rs_baseline(&sys, p.shape.out_bytes(), 8, 80);
        let fused = run_fused_gemm_rs(&sys, &p, 8, &opts(ArbPolicy::T3Mca));
        let ratio = rs.counters.rs_reads as f64 / fused.counters.rs_reads as f64;
        assert!((1.8..3.0).contains(&ratio), "RS read reduction {ratio}");
    }

    #[test]
    fn works_for_various_device_counts() {
        let sys = SystemConfig::table1();
        let p = plan(4096, 2048, 512);
        for devices in [2u64, 3, 4, 8, 16] {
            let res = run_fused_gemm_rs(&sys, &p, devices, &opts(ArbPolicy::T3Mca));
            assert!(res.total > SimTime::ZERO, "devices={devices}");
            assert_eq!(res.tracker_done.len(), devices as usize);
        }
    }
}
