//! Baseline collective kernels (ring reduce-scatter / all-gather), executed
//! the way modern collective libraries run them: as GPU kernels whose CUs
//! read, reduce, and store data (Figure 3, Figure 10a).
//!
//! The CU count matters (Figure 6): a collective kernel given few CUs
//! cannot source enough memory requests to saturate the ring link, which is
//! precisely the compute-sharing penalty T3 avoids. The per-element work of
//! ring-RS is 2 loads + 1 remote store, so a kernel with aggregate issue
//! bandwidth `B` feeds the link at ~`B/3` (AG: 1 load + 1 store ⇒ `B/2`).
//!
//! `run_rs_nmc` models the same ring with near-memory-compute reductions
//! and DMA-driven transfers (no CUs): incoming chunks are op-and-store
//! updates, sends need one read, and the final local reduction disappears —
//! the Ideal-RS+NMC configuration of §5.3.

use crate::config::{ArbPolicy, SystemConfig};
use crate::hw::hbm::{GroupId, TrafficClass, Txn, TxnKind};
use crate::hw::mc::Stream;
use crate::sim::stats::DramCounters;
use crate::sim::time::SimTime;

use super::{Ev, GroupTag, Runner, PACE_BATCH};

/// Result of one collective run.
#[derive(Debug, Clone)]
pub struct CollectiveRunResult {
    pub time: SimTime,
    pub counters: DramCounters,
    /// Per-step completion times.
    pub step_ends: Vec<SimTime>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    /// CU-executed ring reduce-scatter.
    RsCu,
    /// CU-executed ring all-gather.
    AgCu,
    /// DMA + near-memory-compute ring reduce-scatter (no CUs).
    RsNmc,
}

/// Baseline CU-executed ring reduce-scatter of `bytes` over `devices`
/// devices using `cus` compute units.
pub fn run_rs_baseline(sys: &SystemConfig, bytes: u64, devices: u64, cus: u32) -> CollectiveRunResult {
    run_ring(sys, bytes, devices, cus, Kind::RsCu)
}

/// Baseline CU-executed ring all-gather.
pub fn run_ag_baseline(sys: &SystemConfig, bytes: u64, devices: u64, cus: u32) -> CollectiveRunResult {
    run_ring(sys, bytes, devices, cus, Kind::AgCu)
}

/// NMC-assisted, DMA-driven ring reduce-scatter (Ideal-RS+NMC).
pub fn run_rs_nmc(sys: &SystemConfig, bytes: u64, devices: u64) -> CollectiveRunResult {
    run_ring(sys, bytes, devices, 0, Kind::RsNmc)
}

struct StepCtx {
    read_group: GroupId,
    ingress_group: GroupId,
}

fn run_ring(sys: &SystemConfig, bytes: u64, devices: u64, cus: u32, kind: Kind) -> CollectiveRunResult {
    assert!(devices >= 2);
    let chunk = bytes / devices;
    assert!(chunk > 0, "chunk must be non-empty");
    let steps = (devices - 1) as u32;

    // Effective rates. Per ring-RS element the kernel does 2 loads (own
    // partial + received copy) + 1 remote store, except the first step
    // which only loads the local copy; AG forwards with 1 load + 1 store.
    let link_bw = sys.link.per_dir_bw_gbps;
    let (feed_bw, read_bw, ingress_kind, read_class, write_class) = match kind {
        Kind::RsCu => {
            let cu_bw = sys.gpu.cu_issue_bw_gbps(cus);
            (cu_bw / 3.0, cu_bw * 2.0 / 3.0, TxnKind::Write, TrafficClass::RsRead, TrafficClass::RsWrite)
        }
        Kind::AgCu => {
            let cu_bw = sys.gpu.cu_issue_bw_gbps(cus);
            (cu_bw / 2.0, cu_bw / 2.0, TxnKind::Write, TrafficClass::AgRead, TrafficClass::AgWrite)
        }
        Kind::RsNmc => (
            f64::INFINITY, // DMA feeds the link at link rate
            sys.mem.total_bw_gbps,
            TxnKind::NmcUpdate,
            TrafficClass::RsRead,
            TrafficClass::RsWrite,
        ),
    };
    let read_bytes_for = |step: u32| match kind {
        // First send reads only the local copy; later sends fuse the
        // reduce of the previous receive (2 reads).
        Kind::RsCu => {
            if step == 0 {
                chunk
            } else {
                2 * chunk
            }
        }
        Kind::AgCu => chunk,
        Kind::RsNmc => chunk, // partial already merged by NMC
    };

    let mut r = Runner::new(sys, ArbPolicy::ComputePriority);
    let mut step_ends = Vec::with_capacity(steps as usize + 1);
    let mut tags: Vec<(GroupTag, SimTime)> = Vec::new();

    // Start a step: paced local reads, egress reservation, mirrored ingress.
    let mut ctx: Vec<StepCtx> = Vec::with_capacity(steps as usize);
    macro_rules! start_step {
        ($r:expr, $step:expr) => {{
            let now = $r.now();
            let read_txns = $r.mem.txns_for(read_bytes_for($step));
            let rg = $r.register_group(read_txns, GroupTag::StepReads($step));
            $r.schedule_issue($step, read_txns, now, read_bw, PACE_BATCH);
            let w = $r.link_out.reserve_rate_limited(now, chunk, feed_bw);
            $r.q.schedule(w.done, Ev::EgressDone { pos: $step });
            let in_txns = $r.mem.txns_for(chunk);
            let ig = $r.register_group(in_txns, GroupTag::StepIngress($step));
            let in_rate = feed_bw.min(link_bw);
            $r.schedule_ingress($step, in_txns, w.start + $r.sys.link.latency, in_rate, PACE_BATCH);
            ctx.push(StepCtx {
                read_group: rg,
                ingress_group: ig,
            });
        }};
    }
    start_step!(r, 0);

    // Step completion = reads + ingress + egress (3 conditions).
    let mut remaining = 3u8;
    let mut step = 0u32;
    let mut in_final_reduce = false;

    while let Some((_, ev)) = r.next_event() {
        r.drain_tags(&mut tags);
        for (tag, _blocked) in tags.drain(..) {
            match tag {
                GroupTag::StepReads(s) if s == step && !in_final_reduce => {
                    remaining = remaining.saturating_sub(1)
                }
                GroupTag::StepIngress(s) if s == step => remaining = remaining.saturating_sub(1),
                GroupTag::StepReads(s) if in_final_reduce && s == steps => {
                    // Final-reduce reads done: write the reduced result.
                    r.submit_tagged(chunk, TxnKind::Write, Stream::Compute, write_class, GroupTag::Drain);
                }
                _ => {}
            }
        }
        match ev {
            Ev::EgressDone { pos } if pos == step && !in_final_reduce => {
                remaining = remaining.saturating_sub(1)
            }
            Ev::Issue { step: s, n } => {
                let g = ctx[s as usize].read_group;
                let t = Txn {
                    kind: TxnKind::Read,
                    stream: Stream::Compute,
                    class: read_class,
                    group: g,
                };
                r.mem.submit_burst(n as u64, t, &mut r.q);
            }
            Ev::Ingress { pos, n } => {
                let t = Txn {
                    kind: ingress_kind,
                    stream: Stream::Comm,
                    class: write_class,
                    group: ctx[pos as usize].ingress_group,
                };
                r.mem.submit_burst(n as u64, t, &mut r.q);
            }
            _ => {}
        }
        if remaining == 0 {
            step_ends.push(r.now());
            remaining = u8::MAX;
            if step + 1 < steps {
                step += 1;
                remaining = 3;
                start_step!(r, step);
            } else if kind == Kind::RsCu && !in_final_reduce {
                // Baseline final local reduction: read own + received copy,
                // write the reduced result. NMC folds this into the last
                // ingress update (§4.3), AG has no reduction.
                in_final_reduce = true;
                let now = r.now();
                let read_txns = r.mem.txns_for(2 * chunk);
                let rg = r.register_group(read_txns, GroupTag::StepReads(steps));
                r.schedule_issue(steps, read_txns, now, read_bw, PACE_BATCH);
                ctx.push(StepCtx {
                    read_group: rg,
                    ingress_group: GroupId::NONE,
                });
            }
        }
    }
    debug_assert!(r.mem.idle());
    let time = r.now();
    step_ends.push(time);

    CollectiveRunResult {
        time,
        counters: r.mem.counters,
        step_ends,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    const MB: u64 = 1 << 20;

    #[test]
    fn rs_link_bound_with_all_cus() {
        let sys = SystemConfig::table1();
        // 64 MB over 8 GPUs: alpha-beta lower bound (N-1)/N * S / link.
        let res = run_rs_baseline(&sys, 64 * MB, 8, 80);
        let lb = (7.0 / 8.0) * (64.0 * MB as f64) / (75.0 * 1e9);
        let sim = res.time.as_secs_f64();
        let ratio = sim / lb;
        assert!((1.0..1.5).contains(&ratio), "sim/alpha-beta = {ratio}");
    }

    #[test]
    fn rs_slows_with_few_cus() {
        // Figure 6: AR with 8 CUs ~40% slower than with all CUs.
        let sys = SystemConfig::table1();
        let t80 = run_rs_baseline(&sys, 96 * MB, 8, 80).time;
        let t8 = run_rs_baseline(&sys, 96 * MB, 8, 8).time;
        let t16 = run_rs_baseline(&sys, 96 * MB, 8, 16).time;
        let slow8 = t8.as_ps() as f64 / t80.as_ps() as f64;
        let slow16 = t16.as_ps() as f64 / t80.as_ps() as f64;
        assert!((1.25..1.8).contains(&slow8), "8-CU slowdown {slow8}");
        assert!((1.0..1.25).contains(&slow16), "16-CU slowdown {slow16}");
    }

    #[test]
    fn ag_faster_than_rs_same_size() {
        let sys = SystemConfig::table1();
        let rs = run_rs_baseline(&sys, 64 * MB, 8, 80).time;
        let ag = run_ag_baseline(&sys, 64 * MB, 8, 80).time;
        assert!(ag <= rs, "AG {ag} vs RS {rs}");
    }

    #[test]
    fn rs_traffic_accounting() {
        let sys = SystemConfig::table1();
        let n = 8u64;
        let bytes = 64 * MB;
        let chunk = bytes / n;
        let res = run_rs_baseline(&sys, bytes, n, 80);
        // reads: 1 (first send) + 2 per later send + 2 final reduce
        //      = 2N-1 chunks
        let expect_reads = (2 * n - 1) * chunk;
        // writes: N-1 incoming + 1 final reduced result = N chunks
        let expect_writes = n * chunk;
        let slack = 64 * sys.mem.txn_bytes * n;
        assert!(res.counters.rs_reads >= expect_reads && res.counters.rs_reads <= expect_reads + slack,
            "reads {} vs {}", res.counters.rs_reads, expect_reads);
        assert!(res.counters.rs_writes >= expect_writes && res.counters.rs_writes <= expect_writes + slack,
            "writes {} vs {}", res.counters.rs_writes, expect_writes);
    }

    #[test]
    fn nmc_rs_faster_and_leaner_than_baseline() {
        let sys = SystemConfig::table1();
        let base = run_rs_baseline(&sys, 96 * MB, 8, 80);
        let nmc = run_rs_nmc(&sys, 96 * MB, 8);
        assert!(nmc.time < base.time);
        // §6.1.1: NMC speeds RS by a few percent at TP=8.
        let gain = base.time.as_ps() as f64 / nmc.time.as_ps() as f64;
        assert!((1.01..1.25).contains(&gain), "NMC RS gain {gain}");
        // NMC reads one copy per step, no final-reduce reads.
        assert!(nmc.counters.rs_reads < base.counters.rs_reads);
    }

    #[test]
    fn nmc_benefit_shrinks_with_tp() {
        let sys = SystemConfig::table1();
        let gain = |tp: u64| {
            let b = run_rs_baseline(&sys, 96 * MB, tp, 80).time.as_ps() as f64;
            let n = run_rs_nmc(&sys, 96 * MB, tp).time.as_ps() as f64;
            b / n
        };
        assert!(gain(8) > gain(16), "NMC gain should shrink as TP grows");
    }

    #[test]
    fn rs_scales_linearly_in_size() {
        let sys = SystemConfig::table1();
        let t1 = run_rs_baseline(&sys, 24 * MB, 4, 80).time.as_secs_f64();
        let t2 = run_rs_baseline(&sys, 96 * MB, 4, 80).time.as_secs_f64();
        let ratio = t2 / t1;
        assert!((3.3..4.6).contains(&ratio), "scaling ratio {ratio}");
    }

    #[test]
    fn step_count_matches_ring() {
        let sys = SystemConfig::table1();
        let res = run_ag_baseline(&sys, 32 * MB, 8, 80);
        // N-1 steps + final timestamp
        assert_eq!(res.step_ends.len(), 8);
    }
}
