//! Baseline collective kernels (ring reduce-scatter / all-gather), executed
//! the way modern collective libraries run them: as GPU kernels whose CUs
//! read, reduce, and store data (Figure 3, Figure 10a).
//!
//! The CU count matters (Figure 6): a collective kernel given few CUs
//! cannot source enough memory requests to saturate the ring link, which is
//! precisely the compute-sharing penalty T3 avoids. The per-element work of
//! ring-RS is 2 loads + 1 remote store, so a kernel with aggregate issue
//! bandwidth `B` feeds the link at ~`B/3` (AG: 1 load + 1 store ⇒ `B/2`).
//!
//! [`RingKind::RsNmc`] models the same ring with near-memory-compute
//! reductions and DMA-driven transfers (no CUs): incoming chunks are
//! op-and-store updates, sends need one read, and the final local reduction
//! disappears — the Ideal-RS+NMC configuration of §5.3.
//!
//! Like the fused engine, the ring is factored as a per-rank machine
//! ([`RingRank`]): each ring step reserves an egress window on the rank's
//! downstream link and emits a [`RingMsg`] telling the receiver when and at
//! what rate the hop's bytes arrive. The entry points below are loopback
//! drivers (homogeneous mirror, §5.1.1); [`crate::cluster`] drives `tp`
//! interacting ranks with per-rank start offsets (a straggler's late
//! kernel delays exactly the chunks that transit it) and per-edge links.

use crate::config::{ArbPolicy, LinkConfig, SystemConfig};
use crate::hw::hbm::{GroupId, TrafficClass, Txn, TxnKind};
use crate::hw::mc::Stream;
use crate::sim::stats::DramCounters;
use crate::sim::time::SimTime;
use crate::trace::{DepKind, Lane, RankTrace, SinkMode, SpanLabel};

use super::{Ev, GroupTag, Runner, PACE_BATCH};

/// Result of one collective run. `time` is the absolute completion time of
/// the rank's calendar — for a rank started at an offset it includes that
/// offset.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectiveRunResult {
    /// Absolute completion time of the rank's calendar.
    pub time: SimTime,
    /// DRAM traffic counters for the run.
    pub counters: DramCounters,
    /// Per-step completion times.
    pub step_ends: Vec<SimTime>,
    /// Timeline trace (when [`RingRank::enable_trace`] was called).
    pub timeline: Option<RankTrace>,
    /// Total bytes the egress link carried (trace reconciliation).
    pub link_bytes: u64,
}

/// Which ring collective a [`RingRank`] executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RingKind {
    /// CU-executed ring reduce-scatter.
    RsCu,
    /// CU-executed ring all-gather.
    AgCu,
    /// DMA + near-memory-compute ring reduce-scatter (no CUs).
    RsNmc,
}

/// A cross-rank ring message: one hop's bytes arrive at the receiver from
/// `start` (sender's egress start + hop latency), paced at `rate_gbps`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RingMsg {
    /// Ring step the transfer belongs to.
    pub step: u32,
    /// First-byte arrival time at the receiver.
    pub start: SimTime,
    /// Arrival rate (sender's feed rate capped by the hop's bandwidth).
    pub rate_gbps: f64,
}

/// Baseline CU-executed ring reduce-scatter of `bytes` over `devices`
/// devices using `cus` compute units.
pub fn run_rs_baseline(sys: &SystemConfig, bytes: u64, devices: u64, cus: u32) -> CollectiveRunResult {
    run_ring(sys, bytes, devices, cus, RingKind::RsCu)
}

/// Baseline CU-executed ring all-gather.
pub fn run_ag_baseline(sys: &SystemConfig, bytes: u64, devices: u64, cus: u32) -> CollectiveRunResult {
    run_ring(sys, bytes, devices, cus, RingKind::AgCu)
}

/// NMC-assisted, DMA-driven ring reduce-scatter (Ideal-RS+NMC).
pub fn run_rs_nmc(sys: &SystemConfig, bytes: u64, devices: u64) -> CollectiveRunResult {
    run_ring(sys, bytes, devices, 0, RingKind::RsNmc)
}

/// Construction parameters of one [`RingRank`].
#[derive(Debug, Clone)]
pub struct RingRankSpec {
    /// Total collective payload (all chunks).
    pub bytes: u64,
    /// Ring size.
    pub devices: u64,
    /// CUs granted to the kernel (ignored by [`RingKind::RsNmc`]).
    pub cus: u32,
    /// Which ring collective (RS/AG) and reduction path to run.
    pub kind: RingKind,
    /// When this rank's kernel launches (offset composition: e.g. after
    /// the rank's — possibly skewed — producer GEMM).
    pub start: SimTime,
    /// This rank's egress edge (to its downstream ring neighbor).
    pub link: LinkConfig,
    /// CU issue-rate slowdown factor (skew model; 1.0 = nominal). The
    /// NMC/DMA path is not CU-bound and ignores it.
    pub issue_scale: f64,
}

/// One rank of a baseline ring collective: an event-driven step machine
/// over its own [`Runner`]. Drive with [`RingRank::step`] /
/// [`RingRank::deliver`] like [`crate::engine::fused::FusedRank`].
pub struct RingRank {
    r: Runner,
    kind: RingKind,
    chunk: u64,
    steps: u32,
    feed_bw: f64,
    read_bw: f64,
    ingress_kind: TxnKind,
    read_class: TrafficClass,
    write_class: TrafficClass,
    started: bool,
    /// Current ring step; `steps` once all hops completed.
    step: u32,
    in_final_reduce: bool,
    reads_done: Vec<bool>,
    ingress_done: Vec<bool>,
    egress_done: Vec<bool>,
    /// Per-step local-read groups; index `steps` is the final reduce.
    read_groups: Vec<GroupId>,
    ingress_groups: Vec<GroupId>,
    step_ends: Vec<SimTime>,
    tags: Vec<(GroupTag, SimTime)>,
}

impl RingRank {
    /// Build one rank's machine from its spec.
    pub fn new(sys: &SystemConfig, spec: &RingRankSpec) -> Self {
        assert!(spec.devices >= 2);
        let chunk = spec.bytes / spec.devices;
        assert!(chunk > 0, "chunk must be non-empty");
        let steps = (spec.devices - 1) as u32;
        debug_assert!(spec.issue_scale >= 1.0);

        // Effective rates. Per ring-RS element the kernel does 2 loads (own
        // partial + received copy) + 1 remote store, except the first step
        // which only loads the local copy; AG forwards with 1 load + 1 store.
        let (feed_bw, read_bw, ingress_kind, read_class, write_class) = match spec.kind {
            RingKind::RsCu => {
                let cu_bw = sys.gpu.cu_issue_bw_gbps(spec.cus) / spec.issue_scale;
                (
                    cu_bw / 3.0,
                    cu_bw * 2.0 / 3.0,
                    TxnKind::Write,
                    TrafficClass::RsRead,
                    TrafficClass::RsWrite,
                )
            }
            RingKind::AgCu => {
                let cu_bw = sys.gpu.cu_issue_bw_gbps(spec.cus) / spec.issue_scale;
                (
                    cu_bw / 2.0,
                    cu_bw / 2.0,
                    TxnKind::Write,
                    TrafficClass::AgRead,
                    TrafficClass::AgWrite,
                )
            }
            RingKind::RsNmc => (
                f64::INFINITY, // DMA feeds the link at link rate
                sys.mem.total_bw_gbps,
                TxnKind::NmcUpdate,
                TrafficClass::RsRead,
                TrafficClass::RsWrite,
            ),
        };

        let mut r = Runner::with_link(sys, ArbPolicy::ComputePriority, spec.link.clone());
        // The rank's kernel launches at `spec.start`.
        r.q.schedule(spec.start, Ev::Marker { step: 0, what: 0 });

        RingRank {
            r,
            kind: spec.kind,
            chunk,
            steps,
            feed_bw,
            read_bw,
            ingress_kind,
            read_class,
            write_class,
            started: false,
            step: 0,
            in_final_reduce: false,
            reads_done: vec![false; steps as usize],
            ingress_done: vec![false; steps as usize],
            egress_done: vec![false; steps as usize],
            read_groups: vec![GroupId::NONE; steps as usize + 1],
            ingress_groups: vec![GroupId::NONE; steps as usize],
            step_ends: Vec::with_capacity(steps as usize + 1),
            tags: Vec::new(),
        }
    }

    fn read_bytes_for(&self, step: u32) -> u64 {
        match self.kind {
            // First send reads only the local copy; later sends fuse the
            // reduce of the previous receive (2 reads).
            RingKind::RsCu => {
                if step == 0 {
                    self.chunk
                } else {
                    2 * self.chunk
                }
            }
            RingKind::AgCu => self.chunk,
            RingKind::RsNmc => self.chunk, // partial already merged by NMC
        }
    }

    /// Record this rank's timeline (`t3::trace`): link egress/ingress
    /// windows and DRAM service lanes. Purely observational.
    pub fn enable_trace(&mut self, rank: u64) {
        self.r.enable_trace(rank);
    }

    /// [`RingRank::enable_trace`] with an explicit sink mode.
    pub fn enable_trace_with(&mut self, rank: u64, mode: SinkMode) {
        self.r.enable_trace_with(rank, mode);
    }

    /// Rebind this rank's egress (fabric integration). Must be called
    /// before the first event is processed.
    pub fn attach_port(&mut self, port: crate::fabric::EgressPort) {
        debug_assert!(!self.started, "attach_port after the rank started");
        self.r.link_out = port;
    }

    /// Start ring step `s`: paced local reads, an egress reservation on the
    /// downstream edge, and a [`RingMsg`] telling the receiver the hop's
    /// arrival window.
    fn start_step(&mut self, s: u32, out: &mut Vec<RingMsg>) {
        let now = self.r.now();
        if s > 0 {
            // Intra-rank step ordering: step s launches at step s-1's end.
            let prev = self.step_ends[s as usize - 1];
            self.r.note_local_edge(DepKind::Step, prev, now);
        }
        let read_txns = self.r.mem.txns_for(self.read_bytes_for(s));
        self.read_groups[s as usize] = self.r.register_group(read_txns, GroupTag::StepReads(s));
        self.r.schedule_issue(s, read_txns, now, self.read_bw, PACE_BATCH);
        let w = self
            .r
            .egress_rate_limited(now, self.chunk, self.feed_bw, SpanLabel::Chunk(s));
        self.r.q.schedule(w.done, Ev::EgressDone { pos: s });
        out.push(RingMsg {
            step: s,
            start: w.arrive_first,
            rate_gbps: self.feed_bw.min(self.r.link_out.bw_gbps()),
        });
    }

    /// Time of this rank's next pending event.
    pub fn next_time(&self) -> Option<SimTime> {
        self.r.q.peek_time()
    }

    /// Apply the upstream neighbor's hop-arrival message: pace the chunk's
    /// ingress into local memory from `msg.start` at `msg.rate_gbps`.
    /// Arrivals are accepted even before this rank reaches the step (a
    /// faster upstream under skew) — the network does not wait.
    pub fn deliver(&mut self, msg: &RingMsg) {
        let s = msg.step as usize;
        debug_assert!(self.ingress_groups[s] == GroupId::NONE, "duplicate hop for step {s}");
        let in_txns = self.r.mem.txns_for(self.chunk);
        self.ingress_groups[s] = self.r.register_group(in_txns, GroupTag::StepIngress(msg.step));
        if self.r.sink.enabled() {
            // The arrival window mirrors the sender's egress window: same
            // duration (chunk at the capped rate), shifted by the hop.
            let end = msg.start + SimTime::transfer(self.chunk, msg.rate_gbps);
            self.r
                .sink
                .span(Lane::LinkIngress, msg.start, end, self.chunk, SpanLabel::Chunk(msg.step));
        }
        self.r
            .schedule_ingress(msg.step, in_txns, msg.start, msg.rate_gbps, PACE_BATCH);
    }

    /// Process one event; hop messages for the downstream neighbor are
    /// appended to `out`. Returns `false` when the calendar is empty.
    pub fn step(&mut self, out: &mut Vec<RingMsg>) -> bool {
        let Some((_, ev)) = self.r.next_event() else {
            return false;
        };
        let mut tags = std::mem::take(&mut self.tags);
        self.r.drain_tags(&mut tags);
        for (tag, _blocked) in tags.drain(..) {
            match tag {
                GroupTag::StepReads(s) if self.in_final_reduce && s == self.steps => {
                    // Final-reduce reads done: write the reduced result.
                    self.r.submit_tagged(
                        self.chunk,
                        TxnKind::Write,
                        Stream::Compute,
                        self.write_class,
                        GroupTag::Drain,
                    );
                }
                GroupTag::StepReads(s) => self.reads_done[s as usize] = true,
                GroupTag::StepIngress(s) => self.ingress_done[s as usize] = true,
                _ => {}
            }
        }
        self.tags = tags;

        match ev {
            Ev::Marker { step: 0, .. } if !self.started => {
                self.started = true;
                self.start_step(0, out);
            }
            Ev::EgressDone { pos } => self.egress_done[pos as usize] = true,
            Ev::Issue { step: s, n } => {
                let t = Txn {
                    kind: TxnKind::Read,
                    stream: Stream::Compute,
                    class: self.read_class,
                    group: self.read_groups[s as usize],
                };
                self.r.mem.submit_burst(n as u64, t, &mut self.r.q);
            }
            Ev::Ingress { pos, n } => {
                let t = Txn {
                    kind: self.ingress_kind,
                    stream: Stream::Comm,
                    class: self.write_class,
                    group: self.ingress_groups[pos as usize],
                };
                self.r.mem.submit_burst(n as u64, t, &mut self.r.q);
            }
            _ => {}
        }

        // Step completion = reads + ingress + egress (3 conditions).
        if self.started && self.step < self.steps {
            let s = self.step as usize;
            if self.reads_done[s] && self.ingress_done[s] && self.egress_done[s] {
                self.step_ends.push(self.r.now());
                self.step += 1;
                if self.step < self.steps {
                    self.start_step(self.step, out);
                } else if self.kind == RingKind::RsCu {
                    // Baseline final local reduction: read own + received
                    // copy, write the reduced result. NMC folds this into
                    // the last ingress update (§4.3), AG has no reduction.
                    self.in_final_reduce = true;
                    let now = self.r.now();
                    let read_txns = self.r.mem.txns_for(2 * self.chunk);
                    self.read_groups[self.steps as usize] =
                        self.r.register_group(read_txns, GroupTag::StepReads(self.steps));
                    self.r
                        .schedule_issue(self.steps, read_txns, now, self.read_bw, PACE_BATCH);
                }
            }
        }
        true
    }

    /// Consume the drained rank into its result.
    pub fn into_result(mut self) -> CollectiveRunResult {
        debug_assert!(self.r.mem.idle());
        let time = self.r.now();
        self.step_ends.push(time);
        let timeline = self.r.take_timeline(time);
        CollectiveRunResult {
            time,
            counters: self.r.mem.counters,
            step_ends: self.step_ends,
            timeline,
            link_bytes: self.r.link_out.bytes_carried(),
        }
    }
}

/// Loopback driver: one rank, its hop messages mirrored back to itself
/// (homogeneous devices, §5.1.1).
fn run_ring(sys: &SystemConfig, bytes: u64, devices: u64, cus: u32, kind: RingKind) -> CollectiveRunResult {
    run_ring_opt(sys, bytes, devices, cus, kind, false)
}

/// Loopback ring driver with timeline tracing enabled ([`RingKind`]
/// selects the collective; `cus` is ignored by [`RingKind::RsNmc`],
/// exactly as in the untraced entry points). Every simulated quantity is
/// bit-identical to the untraced run.
#[deprecated(
    since = "0.2.0",
    note = "trace capture is an ExecOpts field now: run a Ring phase through \
            cluster::execute, or run_collective(traced = true)"
)]
pub fn run_ring_traced(
    sys: &SystemConfig,
    bytes: u64,
    devices: u64,
    cus: u32,
    kind: RingKind,
) -> CollectiveRunResult {
    run_ring_opt(sys, bytes, devices, cus, kind, true)
}

fn run_ring_opt(
    sys: &SystemConfig,
    bytes: u64,
    devices: u64,
    cus: u32,
    kind: RingKind,
    traced: bool,
) -> CollectiveRunResult {
    let spec = RingRankSpec {
        bytes,
        devices,
        cus,
        kind,
        start: SimTime::ZERO,
        link: sys.link.clone(),
        issue_scale: 1.0,
    };
    let mut rank = RingRank::new(sys, &spec);
    if traced {
        rank.enable_trace(0);
    }
    let mut msgs = Vec::new();
    while rank.step(&mut msgs) {
        for m in msgs.drain(..) {
            rank.deliver(&m);
        }
    }
    rank.into_result()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    const MB: u64 = 1 << 20;

    #[test]
    fn rs_link_bound_with_all_cus() {
        let sys = SystemConfig::table1();
        // 64 MB over 8 GPUs: alpha-beta lower bound (N-1)/N * S / link.
        let res = run_rs_baseline(&sys, 64 * MB, 8, 80);
        let lb = (7.0 / 8.0) * (64.0 * MB as f64) / (75.0 * 1e9);
        let sim = res.time.as_secs_f64();
        let ratio = sim / lb;
        assert!((1.0..1.5).contains(&ratio), "sim/alpha-beta = {ratio}");
    }

    #[test]
    fn rs_slows_with_few_cus() {
        // Figure 6: AR with 8 CUs ~40% slower than with all CUs.
        let sys = SystemConfig::table1();
        let t80 = run_rs_baseline(&sys, 96 * MB, 8, 80).time;
        let t8 = run_rs_baseline(&sys, 96 * MB, 8, 8).time;
        let t16 = run_rs_baseline(&sys, 96 * MB, 8, 16).time;
        let slow8 = t8.as_ps() as f64 / t80.as_ps() as f64;
        let slow16 = t16.as_ps() as f64 / t80.as_ps() as f64;
        assert!((1.25..1.8).contains(&slow8), "8-CU slowdown {slow8}");
        assert!((1.0..1.25).contains(&slow16), "16-CU slowdown {slow16}");
    }

    #[test]
    fn ag_faster_than_rs_same_size() {
        let sys = SystemConfig::table1();
        let rs = run_rs_baseline(&sys, 64 * MB, 8, 80).time;
        let ag = run_ag_baseline(&sys, 64 * MB, 8, 80).time;
        assert!(ag <= rs, "AG {ag} vs RS {rs}");
    }

    #[test]
    fn rs_traffic_accounting() {
        let sys = SystemConfig::table1();
        let n = 8u64;
        let bytes = 64 * MB;
        let chunk = bytes / n;
        let res = run_rs_baseline(&sys, bytes, n, 80);
        // reads: 1 (first send) + 2 per later send + 2 final reduce
        //      = 2N-1 chunks
        let expect_reads = (2 * n - 1) * chunk;
        // writes: N-1 incoming + 1 final reduced result = N chunks
        let expect_writes = n * chunk;
        let slack = 64 * sys.mem.txn_bytes * n;
        assert!(res.counters.rs_reads >= expect_reads && res.counters.rs_reads <= expect_reads + slack,
            "reads {} vs {}", res.counters.rs_reads, expect_reads);
        assert!(res.counters.rs_writes >= expect_writes && res.counters.rs_writes <= expect_writes + slack,
            "writes {} vs {}", res.counters.rs_writes, expect_writes);
    }

    #[test]
    fn nmc_rs_faster_and_leaner_than_baseline() {
        let sys = SystemConfig::table1();
        let base = run_rs_baseline(&sys, 96 * MB, 8, 80);
        let nmc = run_rs_nmc(&sys, 96 * MB, 8);
        assert!(nmc.time < base.time);
        // §6.1.1: NMC speeds RS by a few percent at TP=8.
        let gain = base.time.as_ps() as f64 / nmc.time.as_ps() as f64;
        assert!((1.01..1.25).contains(&gain), "NMC RS gain {gain}");
        // NMC reads one copy per step, no final-reduce reads.
        assert!(nmc.counters.rs_reads < base.counters.rs_reads);
    }

    #[test]
    fn nmc_benefit_shrinks_with_tp() {
        let sys = SystemConfig::table1();
        let gain = |tp: u64| {
            let b = run_rs_baseline(&sys, 96 * MB, tp, 80).time.as_ps() as f64;
            let n = run_rs_nmc(&sys, 96 * MB, tp).time.as_ps() as f64;
            b / n
        };
        assert!(gain(8) > gain(16), "NMC gain should shrink as TP grows");
    }

    #[test]
    fn rs_scales_linearly_in_size() {
        let sys = SystemConfig::table1();
        let t1 = run_rs_baseline(&sys, 24 * MB, 4, 80).time.as_secs_f64();
        let t2 = run_rs_baseline(&sys, 96 * MB, 4, 80).time.as_secs_f64();
        let ratio = t2 / t1;
        assert!((3.3..4.6).contains(&ratio), "scaling ratio {ratio}");
    }

    #[test]
    fn step_count_matches_ring() {
        let sys = SystemConfig::table1();
        let res = run_ag_baseline(&sys, 32 * MB, 8, 80);
        // N-1 steps + final timestamp
        assert_eq!(res.step_ends.len(), 8);
    }

    #[test]
    fn start_offset_shifts_the_whole_run() {
        // The rank machine is shift-invariant: starting the kernel at T
        // ends exactly T later than starting at zero (the property the
        // cluster engine's offset composition relies on).
        let sys = SystemConfig::table1();
        let base = run_rs_baseline(&sys, 32 * MB, 4, 80);
        let t0 = SimTime::us(137);
        let spec = RingRankSpec {
            bytes: 32 * MB,
            devices: 4,
            cus: 80,
            kind: RingKind::RsCu,
            start: t0,
            link: sys.link.clone(),
            issue_scale: 1.0,
        };
        let mut rank = RingRank::new(&sys, &spec);
        let mut msgs = Vec::new();
        while rank.step(&mut msgs) {
            for m in msgs.drain(..) {
                rank.deliver(&m);
            }
        }
        let shifted = rank.into_result();
        assert_eq!(shifted.time, base.time + t0);
        assert_eq!(shifted.counters, base.counters);
        for (a, b) in shifted.step_ends.iter().zip(&base.step_ends) {
            assert_eq!(*a, *b + t0);
        }
    }

    #[test]
    fn issue_scale_slows_cu_kernels() {
        let sys = SystemConfig::table1();
        let spec = |scale: f64| RingRankSpec {
            bytes: 32 * MB,
            devices: 4,
            cus: 16,
            kind: RingKind::RsCu,
            start: SimTime::ZERO,
            link: sys.link.clone(),
            issue_scale: scale,
        };
        let run = |s: RingRankSpec| {
            let mut rank = RingRank::new(&sys, &s);
            let mut msgs = Vec::new();
            while rank.step(&mut msgs) {
                for m in msgs.drain(..) {
                    rank.deliver(&m);
                }
            }
            rank.into_result()
        };
        let nominal = run(spec(1.0));
        let slow = run(spec(1.5));
        assert!(slow.time > nominal.time);
        // Scale 1.0 is bit-identical to the plain entry point.
        assert_eq!(nominal, run_rs_baseline(&sys, 32 * MB, 4, 16));
    }
}
