//! # T3: Transparent Tracking & Triggering — full-system reproduction
//!
//! A from-scratch reproduction of the T3 paper (Pati et al., ASPLOS'24):
//! hardware-software co-design for fine-grained overlap of producer GEMMs
//! with the serialized collectives of tensor-parallel Transformers.
//!
//! The crate contains:
//! * a discrete-event multi-GPU simulator ([`sim`], [`hw`], [`engine`])
//!   modeling the paper's Table-1 system at memory-transaction granularity;
//! * the multi-rank [`cluster`] engine — every TP rank as a communicating
//!   event-driven node with per-edge links, supporting rank skew,
//!   stragglers, and two-tier topologies; its uniform configuration
//!   reproduces the single-rank mirror engine bit-for-bit;
//! * the route-aware network [`fabric`] — topology graphs (ring,
//!   fat-tree, 2-D torus, rail-optimized) of hop-by-hop links with finite
//!   per-direction bandwidth, deterministic shortest-path routing, and
//!   visible congestion, backing the cluster's fabric axis;
//! * the [`trace`] subsystem — deterministic, zero-cost-when-off timeline
//!   capture on per-rank resource lanes, threaded through every engine:
//!   Chrome/Perfetto export, trace-derived overlap / exposed-communication
//!   / critical-path metrics, structural trace diffs, and the invariant
//!   checkers behind the property tests;
//! * the T3 mechanisms: the [`tracker`] at the memory controller, the
//!   producer output [`addrspace`] configuration, near-memory-compute DRAM
//!   semantics and the MCA arbitration policy ([`hw::mc`]);
//! * [`collectives`] — analytic, simulated (baseline + T3-fused), and
//!   *functional* (real-buffer, bit-exact) implementations;
//! * the declarative [`experiment`] API — the public entry point for
//!   running simulations: composable [`experiment::ScenarioSpec`]s, a
//!   named scenario registry, declarative [`experiment::ExperimentSpec`]
//!   grids executed on a work-stealing thread pool, and queryable
//!   [`experiment::ResultSet`]s;
//! * a Transformer [`models`] zoo and end-to-end iteration projection
//!   ([`exec`]) reproducing the paper's Figures 4/15/16/18/19/20;
//! * a tensor-parallel [`coordinator`] that executes real numerics through
//!   AOT-compiled JAX/Pallas artifacts via the PJRT [`runtime`] (build
//!   with `--features pjrt`);
//! * the figure/table regeneration [`harness`], a thin view layer over
//!   [`experiment`].
//!
//! * the static [`analysis`] subsystem — a Program/fabric verifier with
//!   stable diagnostic codes (`T3E`/`T3W`), symbolic alpha-beta time
//!   bounds, and the fail-fast pre-flight behind `t3 lint` and
//!   [`cluster::execute`].
//!
//! See DESIGN.md for the architecture (including the paper-section →
//! source-file map) and README.md for the quickstart and CLI tour.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod addrspace;
pub mod analysis;
pub mod cluster;
pub mod collectives;
pub mod coordinator;
pub mod config;
pub mod error;
pub mod experiment;
pub mod fabric;
pub mod gemm;
pub mod harness;
pub mod hw;
pub mod sim;
pub mod testkit;
pub mod trace;
pub mod tracker;
pub mod engine;
pub mod exec;
pub mod models;
pub mod obs;
pub mod runtime;
