#!/usr/bin/env python3
"""Bench regression gate: compare regenerated BENCH_*.json tables against
their committed baselines and fail on a >25% wall-clock regression.

Usage:
    bench_check.py BASELINE CURRENT [BASELINE CURRENT ...]

Each pair is a committed baseline snapshot and the freshly regenerated
table (same schema: a top-level ``rows`` list of flat dicts). Rows are
matched across the two files by their identity fields (every
non-float value: ``tp``, ``variant``, ...). Within matched rows, two
metric families gate:

* ``*_wall_s``  — wall-clock seconds, regression when current > 1.25x
  baseline;
* ``*_per_s``   — throughput, regression when current < baseline / 1.25.

Baselines with no rows are skipped (the canonical repo commits
empty-row tables; CI fills them), as are metrics absent from either
side — so schema growth never trips the gate. Tiny absolute values
(< 1e-6) are ignored: they are timer noise, not signal.
"""

import json
import sys

THRESHOLD = 1.25
NOISE_FLOOR = 1e-6


def row_key(row):
    """Identity of a row: every non-float field, sorted for stability."""
    return tuple(sorted((k, v) for k, v in row.items() if not isinstance(v, float)))


def metrics(row):
    out = {}
    for k, v in row.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            continue
        if k.endswith("_wall_s") or k.endswith("_per_s"):
            out[k] = float(v)
    return out


def check_pair(baseline_path, current_path):
    """Return a list of regression messages for one baseline/current pair."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(current_path) as f:
        current = json.load(f)

    base_rows = baseline.get("rows", [])
    cur_rows = current.get("rows", [])
    if not base_rows:
        print(f"skip {baseline_path}: baseline has no rows")
        return []
    if not cur_rows:
        return [f"{current_path}: regenerated table has no rows"]

    cur_by_key = {row_key(r): r for r in cur_rows}
    problems = []
    compared = 0
    for b in base_rows:
        key = row_key(b)
        c = cur_by_key.get(key)
        if c is None:
            print(f"note {current_path}: no current row matching {dict(key)}")
            continue
        cm = metrics(c)
        for name, base_val in metrics(b).items():
            cur_val = cm.get(name)
            if cur_val is None:
                continue
            if max(abs(base_val), abs(cur_val)) < NOISE_FLOOR:
                continue
            label = f"{current_path} {dict(key)} {name}"
            if name.endswith("_wall_s") and cur_val > base_val * THRESHOLD:
                problems.append(
                    f"{label}: {cur_val:.4f}s vs baseline {base_val:.4f}s "
                    f"({cur_val / base_val:.2f}x, limit {THRESHOLD}x)"
                )
            elif name.endswith("_per_s") and cur_val * THRESHOLD < base_val:
                problems.append(
                    f"{label}: {cur_val:.1f}/s vs baseline {base_val:.1f}/s "
                    f"({base_val / max(cur_val, NOISE_FLOOR):.2f}x slower, "
                    f"limit {THRESHOLD}x)"
                )
            else:
                compared += 1
    print(f"ok {current_path}: {compared} metrics within {THRESHOLD}x of {baseline_path}")
    return problems


def main(argv):
    if len(argv) < 2 or len(argv) % 2 != 0:
        print(__doc__)
        return 2
    problems = []
    for i in range(0, len(argv), 2):
        problems.extend(check_pair(argv[i], argv[i + 1]))
    for p in problems:
        print(f"REGRESSION {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
