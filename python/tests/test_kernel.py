"""L1 correctness: the Pallas tiled GEMM vs the pure-jnp oracle.

This is the core correctness signal for the compute hot-spot: exact tile
coverage, dtype handling, and the §4.4 staggered grid-order equivalence
(the transparency claim — reordering tile production must not change the
numerics).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gemm import (
    matmul,
    matmul_staggered,
    staggered_row_order,
)
from compile.kernels.ref import matmul_ref, sliced_gemm_allreduce_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


TOL = {jnp.float32.dtype: 1e-5, jnp.bfloat16.dtype: 2e-2}


def assert_matches_ref(x, w, got):
    want = matmul_ref(x, w)
    tol = TOL[got.dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=tol,
        atol=tol * 10,
    )


class TestMatmulBasics:
    def test_square_f32(self):
        x, w = rand(0, (256, 256), jnp.float32), rand(1, (256, 256), jnp.float32)
        assert_matches_ref(x, w, matmul(x, w))

    def test_rectangular(self):
        x, w = rand(2, (128, 96), jnp.float32), rand(3, (96, 384), jnp.float32)
        assert_matches_ref(x, w, matmul(x, w))

    def test_bf16(self):
        x, w = rand(4, (128, 64), jnp.bfloat16), rand(5, (64, 128), jnp.bfloat16)
        got = matmul(x, w)
        assert got.dtype == jnp.bfloat16
        assert_matches_ref(x, w, got)

    def test_small_blocks(self):
        x, w = rand(6, (64, 32), jnp.float32), rand(7, (32, 64), jnp.float32)
        got = matmul(x, w, block_m=32, block_n=32)
        assert_matches_ref(x, w, got)

    def test_rejects_ragged_m(self):
        x, w = rand(8, (100, 64), jnp.float32), rand(9, (64, 128), jnp.float32)
        with pytest.raises(AssertionError):
            matmul(x, w)

    def test_rejects_mismatched_k(self):
        x, w = rand(10, (128, 64), jnp.float32), rand(11, (96, 128), jnp.float32)
        with pytest.raises(AssertionError):
            matmul(x, w)


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 4),
    nt=st.integers(1, 4),
    k=st.sampled_from([1, 3, 32, 100, 256]),
    bm=st.sampled_from([32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis_sweep(mt, nt, k, bm, dtype, seed):
    """Shape/dtype sweep: any tile grid must match the oracle."""
    m, n = mt * bm, nt * bm
    x = rand(seed, (m, k), dtype)
    w = rand(seed + 1, (k, n), dtype)
    got = matmul(x, w, block_m=bm, block_n=bm)
    assert got.shape == (m, n)
    assert_matches_ref(x, w, got)


class TestStaggeredOrder:
    def test_row_order_is_permutation(self):
        for tiles_m, devices in [(8, 4), (9, 3), (16, 8), (5, 2)]:
            for d in range(devices):
                order = staggered_row_order(tiles_m, devices, d)
                assert sorted(order) == list(range(tiles_m)), (tiles_m, devices, d)

    def test_devices_offset_by_one_chunk(self):
        order0 = staggered_row_order(8, 4, 0)
        order1 = staggered_row_order(8, 4, 1)
        # device 0 starts with chunk 1 (rows 2,3), device 1 with chunk 2.
        assert order0[:2] == [2, 3]
        assert order1[:2] == [4, 5]

    @pytest.mark.parametrize("devices", [2, 4])
    @pytest.mark.parametrize("device_id", [0, 1])
    def test_staggered_matches_plain(self, devices, device_id):
        """§4.4: the staggered schedule is an index-map-only change and
        must be bit-identical to the row-major kernel."""
        x = rand(20, (512, 96), jnp.float32)
        w = rand(21, (96, 256), jnp.float32)
        plain = matmul(x, w)
        stag = matmul_staggered(x, w, devices=devices, device_id=device_id)
        np.testing.assert_array_equal(np.asarray(plain), np.asarray(stag))


class TestSlicedGemmOracle:
    @pytest.mark.parametrize("tp", [2, 4, 8])
    def test_slicing_preserves_result(self, tp):
        x = rand(30, (128, 256), jnp.float32)
        w = rand(31, (256, 128), jnp.float32)
        full = matmul_ref(x, w)
        sliced = sliced_gemm_allreduce_ref(x, w, tp)
        np.testing.assert_allclose(
            np.asarray(sliced), np.asarray(full), rtol=1e-5, atol=1e-4
        )

    def test_partials_differ_from_total(self):
        x = rand(32, (128, 256), jnp.float32)
        w = rand(33, (256, 128), jnp.float32)
        part = matmul_ref(x[:, :128], w[:128, :])
        full = matmul_ref(x, w)
        assert not np.allclose(np.asarray(part), np.asarray(full))
