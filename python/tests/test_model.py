"""L2 correctness: the TP-MLP block's explicit backward vs jax.grad, the
sliced forward vs the unsliced reference, and loss-decrease sanity of the
exact training loop the Rust coordinator runs through PJRT.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import ring_all_reduce_ref

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", False)

TP = model.TRAIN_TP


def init(seed=0, scale=0.05):
    k = jax.random.PRNGKey(seed)
    kx, k1, k2, kt = jax.random.split(k, 4)
    x = jax.random.normal(kx, (model.TOKENS, model.HIDDEN), jnp.float32)
    w1 = jax.random.normal(k1, (model.HIDDEN, model.FFN), jnp.float32) * scale
    w2 = jax.random.normal(k2, (model.FFN, model.HIDDEN), jnp.float32) * scale
    target = model.teacher_targets(x, kt)
    return x, w1, w2, target


def slices(w1, w2):
    f = model.FFN_SLICE
    return [
        (w1[:, d * f:(d + 1) * f], w2[d * f:(d + 1) * f, :]) for d in range(TP)
    ]


class TestForward:
    def test_partials_allreduce_to_full(self):
        x, w1, w2, _ = init()
        parts = [model.mlp_fwd(x, w1s, w2s)[0] for (w1s, w2s) in slices(w1, w2)]
        y = ring_all_reduce_ref(parts)
        h = model._gelu(x @ w1)
        want = h @ w2
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    def test_hpre_matches_slice(self):
        x, w1, w2, _ = init()
        (w1s, w2s) = slices(w1, w2)[1]
        _, h_pre = model.mlp_fwd(x, w1s, w2s)
        np.testing.assert_allclose(
            np.asarray(h_pre), np.asarray(x @ w1s), rtol=1e-4, atol=1e-4
        )


class TestBackward:
    def test_explicit_bwd_matches_jax_grad(self):
        """The hand-written per-device backward must agree with autodiff
        of the full (unsliced) loss, slice for slice."""
        x, w1, w2, target = init()

        def full_loss(w1, w2):
            return model.reference_loss(x, w1, w2, target)

        gw1, gw2 = jax.grad(full_loss, argnums=(0, 1))(w1, w2)

        # TP execution: partial forwards, AR, replicated loss grad,
        # per-device backward.
        sl = slices(w1, w2)
        fwd = [model.mlp_fwd(x, w1s, w2s) for (w1s, w2s) in sl]
        y = ring_all_reduce_ref([f[0] for f in fwd])
        _, dy = model.loss_grad(y, target)
        f = model.FFN_SLICE
        for d, ((w1s, w2s), (_, h_pre)) in enumerate(zip(sl, fwd)):
            dw1s, dw2s = model.mlp_bwd(x, h_pre, w2s, dy)
            np.testing.assert_allclose(
                np.asarray(dw1s),
                np.asarray(gw1[:, d * f:(d + 1) * f]),
                rtol=2e-3,
                atol=1e-6,
                err_msg=f"dW1 slice {d}",
            )
            np.testing.assert_allclose(
                np.asarray(dw2s),
                np.asarray(gw2[d * f:(d + 1) * f, :]),
                rtol=2e-3,
                atol=1e-6,
                err_msg=f"dW2 slice {d}",
            )

    def test_loss_grad_matches_autodiff(self):
        x, _, _, target = init()
        y = x * 0.5
        loss, dy = model.loss_grad(y, target)
        want_loss, want_dy = jax.value_and_grad(
            lambda y: jnp.mean((y - target) ** 2)
        )(y)
        np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(dy), np.asarray(want_dy), rtol=1e-4, atol=1e-7
        )


class TestTrainingLoop:
    @pytest.mark.parametrize("steps,lr", [(40, 0.1)])
    def test_loss_decreases(self, steps, lr):
        """The exact loop train_e2e.rs runs (fwd -> AR -> grad -> bwd ->
        SGD) must reduce the loss monotonically-ish."""
        x, w1, w2, target = init(seed=3)
        sl = [list(s) for s in slices(w1, w2)]
        losses = []
        for _ in range(steps):
            fwd = [model.mlp_fwd(x, w1s, w2s) for (w1s, w2s) in sl]
            y = ring_all_reduce_ref([f[0] for f in fwd])
            loss, dy = model.loss_grad(y, target)
            losses.append(float(loss))
            for d, (w1s, w2s) in enumerate(sl):
                dw1s, dw2s = model.mlp_bwd(x, fwd[d][1], w2s, dy)
                sl[d][0] = w1s - lr * dw1s
                sl[d][1] = w2s - lr * dw2s
        assert losses[-1] < losses[0] * 0.7, losses
        assert all(np.isfinite(l) for l in losses)


class TestShapes:
    def test_artifact_shape_constants(self):
        assert model.FFN == 4 * model.HIDDEN
        assert model.FFN % model.TRAIN_TP == 0
        # tile divisibility for the Pallas kernel (128x128 blocks)
        for dim in (model.TOKENS, model.HIDDEN, model.FFN_SLICE, model.GEMM_M, model.GEMM_N):
            assert dim % 128 == 0, dim
