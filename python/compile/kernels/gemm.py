"""Layer-1: Pallas tiled-GEMM kernel — the producer the T3 hardware fuses.

The kernel embodies the tiling contract the Rust simulator's Tracker
assumes (Section 4.2.1 of the paper, mirrored in ``rust/src/gemm``): every
grid step (the Pallas analog of a workgroup/wavefront) produces one
complete ``block_m x block_n`` output tile; the accumulation (K) dimension
is kept whole inside the kernel, exactly like the tensor-sliced GEMMs of
Figure 5 whose K shrinks with TP degree while the tile grid is unchanged.

Hardware adaptation (paper targets AMD GPUs; Pallas targets the TPU-ish
abstract machine):

* the grid plays the role of the WG launch; one grid step = one WG tile;
* ``BlockSpec`` index maps express the HBM->VMEM staging the GPU kernel
  gets from LDS tiling;
* a GEMM *stage* (set of concurrently-resident WGs) is a contiguous range
  of grid indices;
* the staggered stage->chunk schedule of Section 4.4 is a *grid-index
  permutation implemented purely in the index maps* —
  ``matmul_staggered`` below — leaving the kernel body untouched. That is
  T3's transparency claim, preserved on this substrate.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: matches the Rust `Tiling::default()` (128x128 WG tiles) and
# the MXU-friendly 128-lane shape.
BLOCK_M = 128
BLOCK_N = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One output tile: full-K dot product at fp32 accumulation."""
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _grid_specs(m, n, k, block_m, block_n, stagger=None):
    """Block specs for a (m/bm, n/bn) grid.

    `stagger = (devices, device_id)` permutes the tile-row processing
    order into the staggered chunk schedule of Section 4.4, as a pure
    index-map change (closed-form arithmetic over the grid index — Pallas
    index maps cannot capture arrays).
    """
    if stagger is None:
        def row(i):
            return i
    else:
        devices, device_id = stagger
        tiles_m = m // block_m
        assert tiles_m % devices == 0, (
            f"staggered kernel needs devices | tile rows ({tiles_m} % {devices})"
        )
        rpc = tiles_m // devices  # rows per chunk

        def row(i):
            chunk = (device_id + 1 + i // rpc) % devices
            return chunk * rpc + i % rpc

    x_spec = pl.BlockSpec((block_m, k), lambda i, j: (row(i), 0))
    w_spec = pl.BlockSpec((k, block_n), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_m, block_n), lambda i, j: (row(i), j))
    return x_spec, w_spec, o_spec


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def matmul(x, w, *, block_m=BLOCK_M, block_n=BLOCK_N, interpret=True):
    """`x @ w` via the Pallas tiled kernel.

    Requires m % block_m == 0 and n % block_n == 0 (the production tiling;
    ragged edges are handled by the callers padding, as BLAS kernels do).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % block_m == 0, f"m={m} not a multiple of {block_m}"
    assert n % block_n == 0, f"n={n} not a multiple of {block_n}"
    x_spec, w_spec, o_spec = _grid_specs(m, n, k, block_m, block_n)
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[x_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, w)


def staggered_row_order(tiles_m: int, devices: int, device_id: int):
    """Tile-row processing order for T3's staggered chunk schedule.

    Mirrors `rust/src/gemm::ChunkPlan`: tile-rows are split into `devices`
    chunks (first `tiles_m % devices` chunks one row larger); device `d`
    processes chunks in ring order starting from `(d+1) % devices`.
    """
    base, extra = divmod(tiles_m, devices)
    starts, s = [], 0
    sizes = []
    for c in range(devices):
        sz = base + (1 if c < extra else 0)
        starts.append(s)
        sizes.append(sz)
        s += sz
    order = []
    for i in range(devices):
        c = (device_id + 1 + i) % devices
        order.extend(range(starts[c], starts[c] + sizes[c]))
    return order


@functools.partial(
    jax.jit,
    static_argnames=("devices", "device_id", "block_m", "block_n", "interpret"),
)
def matmul_staggered(
    x, w, *, devices, device_id, block_m=BLOCK_M, block_n=BLOCK_N, interpret=True
):
    """`x @ w` with the tile rows processed in staggered chunk order.

    Numerically identical to :func:`matmul` — each output tile is written
    exactly once — but the production *order* matches what device
    `device_id` of a `devices`-way fused GEMM-RS would follow. The kernel
    body is unchanged: only the BlockSpec index maps differ (§4.4).
    """
    m, k = x.shape
    _, n = w.shape
    assert m % block_m == 0 and n % block_n == 0
    x_spec, w_spec, o_spec = _grid_specs(
        m, n, k, block_m, block_n, stagger=(devices, device_id)
    )
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[x_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, w)
