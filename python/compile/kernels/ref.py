"""Pure-jnp oracles for the Pallas kernel and the collective dataflow.

Everything here is the "obviously correct" implementation the kernels and
the Rust functional collectives are checked against.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, w):
    """Reference GEMM with fp32 accumulation (matches the kernel)."""
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(out_dtype)


def sliced_gemm_allreduce_ref(x, w, tp: int):
    """Tensor-sliced GEMM + all-reduce oracle (Figure 2c).

    Slices the K dimension `tp` ways, computes the per-device partials,
    and sums them — the result every device holds after the AR. Must equal
    `x @ w` up to fp reassociation.
    """
    m, k = x.shape
    assert k % tp == 0
    ks = k // tp
    parts = [
        matmul_ref(x[:, d * ks:(d + 1) * ks], w[d * ks:(d + 1) * ks, :])
        for d in range(tp)
    ]
    return jnp.sum(jnp.stack(parts), axis=0)


def ring_reduce_scatter_ref(arrays):
    """Functional ring-RS oracle: device d ends with chunk d of the sum."""
    n = len(arrays)
    total = jnp.sum(jnp.stack(arrays), axis=0)
    flat = total.reshape(-1)
    base, extra = divmod(flat.shape[0], n)
    chunks, s = [], 0
    for i in range(n):
        sz = base + (1 if i < extra else 0)
        chunks.append(flat[s:s + sz])
        s += sz
    return chunks


def ring_all_reduce_ref(arrays):
    """All-reduce oracle: every device ends with the element-wise sum."""
    return jnp.sum(jnp.stack(arrays), axis=0)


def gelu_ref(x):
    """tanh-approximation GeLU (what the model uses)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))
