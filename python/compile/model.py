"""Layer-2: the tensor-parallel model graphs, built on the Pallas kernel.

Two families of entry points, all AOT-lowered by ``aot.py`` and executed
from Rust through PJRT (Python never runs on the request path):

1. ``sliced_gemm`` — one tensor-sliced producer GEMM (Figure 2c): the
   device's K-slice partial, to be ring-all-reduced by the Rust
   coordinator. Used by the quickstart / inference examples.

2. The tensor-parallel MLP block used by the end-to-end training example
   (``examples/train_e2e.rs``): Megatron-style column-parallel W1 +
   row-parallel W2, so the forward produces a *partial* output that the
   coordinator reduces — exactly the serialized "sliced GEMM -> AR"
   pattern the paper overlaps. The backward is written out explicitly
   (validated against ``jax.grad`` in the tests) so each device's gradient
   GEMMs are also expressible as standalone artifacts.

All GEMMs route through the L1 Pallas kernel so the lowered HLO exercises
the same tiled producer the simulator models.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.gemm import matmul
from .kernels.ref import gelu_ref

# ---------------------------------------------------------------------
# Fixed artifact shapes (the Rust runtime mirrors these constants).
# ---------------------------------------------------------------------

#: quickstart sliced GEMM: [M, K_slice] @ [K_slice, N] -> partial [M, N]
GEMM_M, GEMM_K_SLICE, GEMM_N = 256, 128, 512

#: TP-MLP training block (per device, TP degree TRAIN_TP)
TRAIN_TP = 4
TOKENS = 256        # tokens per step (seq*batch)
HIDDEN = 512        # H
FFN = 2048          # 4H
FFN_SLICE = FFN // TRAIN_TP


def sliced_gemm(x, w):
    """Partial GEMM of one device's K-slice (fp32)."""
    return (matmul(x, w),)


def _gelu(x):
    return gelu_ref(x)


def _dgelu(x):
    """d gelu(x) / dx for the tanh approximation."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    u = c * (x + 0.044715 * x**3)
    t = jnp.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * x**2)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * du


def mlp_fwd(x, w1s, w2s):
    """Per-device forward of the TP MLP block.

    x    : [TOKENS, HIDDEN]     (replicated input)
    w1s  : [HIDDEN, FFN_SLICE]  (column-parallel slice)
    w2s  : [FFN_SLICE, HIDDEN]  (row-parallel slice)

    Returns (y_partial, h_pre): the partial output the coordinator
    all-reduces, and the pre-activation kept for backward.
    """
    h_pre = matmul(x, w1s)
    h = _gelu(h_pre)
    y_partial = matmul(h, w2s)
    return y_partial, h_pre


def loss_grad(y, target):
    """Mean-squared-error loss and its gradient w.r.t. y.

    Runs replicated on every device after the all-reduce (standard TP).
    """
    diff = y - target
    n = jnp.asarray(diff.size, dtype=y.dtype)
    loss = jnp.sum(diff * diff) / n
    dy = 2.0 * diff / n
    return loss, dy


def mlp_bwd(x, h_pre, w2s, dy):
    """Per-device backward of the TP MLP block.

    With the standard TP layout no gradient communication is needed for
    the weight slices (dy is replicated after the AR; x is replicated):

    dW2s = gelu(h_pre)^T @ dy
    dh   = dy @ W2s^T * gelu'(h_pre)
    dW1s = x^T @ dh
    """
    h = _gelu(h_pre)
    dw2s = matmul(h.T, dy)
    dh = matmul(dy, w2s.T) * _dgelu(h_pre)
    dw1s = matmul(x.T, dh)
    return dw1s, dw2s


def mlp_fwd_entry(x, w1s, w2s):
    """Tuple-returning jit entry for AOT lowering."""
    y, h = mlp_fwd(x, w1s, w2s)
    return (y, h)


def loss_grad_entry(y, target):
    loss, dy = loss_grad(y, target)
    return (loss, dy)


def mlp_bwd_entry(x, h_pre, w2s, dy):
    dw1s, dw2s = mlp_bwd(x, h_pre, w2s, dy)
    return (dw1s, dw2s)


def reference_loss(x, w1_full, w2_full, target):
    """Unsliced reference loss for the tests (and tolerance anchor)."""
    h = _gelu(jnp.dot(x, w1_full))
    y = jnp.dot(h, w2_full)
    diff = y - target
    return jnp.sum(diff * diff) / diff.size


def teacher_targets(x, key):
    """Synthetic regression targets from a fixed random teacher network."""
    k1, k2 = jax.random.split(key)
    wt1 = jax.random.normal(k1, (HIDDEN, HIDDEN), jnp.float32) * 0.05
    wt2 = jax.random.normal(k2, (HIDDEN, HIDDEN), jnp.float32) * 0.05
    return jnp.tanh(x @ wt1) @ wt2
