"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts for the Rust
runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. All entries are lowered with
``return_tuple=True`` and unwrapped with ``to_tuple*`` on the Rust side.

Run once via ``make artifacts``; Python never executes on the request
path.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entries():
    """(name, fn, example_args) for every artifact."""
    m = model
    return [
        (
            "sliced_gemm",
            m.sliced_gemm,
            (f32(m.GEMM_M, m.GEMM_K_SLICE), f32(m.GEMM_K_SLICE, m.GEMM_N)),
        ),
        (
            "mlp_fwd",
            m.mlp_fwd_entry,
            (
                f32(m.TOKENS, m.HIDDEN),
                f32(m.HIDDEN, m.FFN_SLICE),
                f32(m.FFN_SLICE, m.HIDDEN),
            ),
        ),
        (
            "loss_grad",
            m.loss_grad_entry,
            (f32(m.TOKENS, m.HIDDEN), f32(m.TOKENS, m.HIDDEN)),
        ),
        (
            "mlp_bwd",
            m.mlp_bwd_entry,
            (
                f32(m.TOKENS, m.HIDDEN),
                f32(m.TOKENS, m.FFN_SLICE),
                f32(m.FFN_SLICE, m.HIDDEN),
                f32(m.TOKENS, m.HIDDEN),
            ),
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--out", default=None, help="(compat) ignored single-file path")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for name, fn, example in entries():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ";".join(
            "x".join(map(str, a.shape)) + ":f32" for a in example
        )
        manifest.append(f"{name} {shapes}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
