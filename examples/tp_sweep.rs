//! TP-degree sweep: where does overlap help most?
//!
//! Sweeps tensor-parallel degree for every zoo model's FC-2 and OP
//! sub-layers, printing Sequential vs T3-MCA vs Ideal and the crossover
//! behavior the paper discusses: small-K OP layers are communication-
//! dominated (speedup tracks the RS share), FC layers balance GEMM and RS
//! near TP=16 where the ideal speedup peaks (§6.1.1).
//!
//! Run: `cargo run --release --example tp_sweep` (no artifacts needed)

use t3::config::SystemConfig;
use t3::exec::{cached_sublayer, sublayer_speedup, Scenario};
use t3::models::{zoo, SubLayer};

fn main() {
    let sys = SystemConfig::table1();
    println!("== TP sweep (Table-1 system) ==");
    println!(
        "{:<12} {:>4} {:<10} {:>10} {:>8} {:>8} {:>8}",
        "model", "tp", "sublayer", "seq ms", "T3-MCA", "ideal", "RS share"
    );
    for m in zoo().into_iter().take(5) {
        for tp in [4u64, 8, 16, 32] {
            if m.hidden % tp != 0 || 3 * m.hidden % tp != 0 {
                continue;
            }
            // Keep the sweep tractable: skip giant-H models at tiny TP
            // (they would not fit real devices there anyway).
            if m.hidden >= 12288 && tp < 16 {
                continue;
            }
            for sub in [SubLayer::Fc2Fwd, SubLayer::OpFwd] {
                let seq = cached_sublayer(&sys, &m, tp, sub, Scenario::Sequential);
                let mca = cached_sublayer(&sys, &m, tp, sub, Scenario::T3Mca);
                let ideal = cached_sublayer(&sys, &m, tp, sub, Scenario::IdealOverlap);
                let rs_share = seq.rs.as_secs_f64() / seq.total.as_secs_f64();
                println!(
                    "{:<12} {:>4} {:<10} {:>10.3} {:>7.2}x {:>7.2}x {:>7.1}%",
                    m.name,
                    tp,
                    sub.name(),
                    seq.total.as_ms_f64(),
                    sublayer_speedup(&seq, &mca),
                    sublayer_speedup(&seq, &ideal),
                    rs_share * 100.0
                );
            }
        }
    }
    println!("\nexpected shape: ideal peaks where GEMM and RS times balance;");
    println!("OP (K=H/tp) exposes RS at high TP; T3-MCA tracks ideal within a few %.");
}
