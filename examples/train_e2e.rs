//! End-to-end driver: tensor-parallel training through the full stack.
//!
//! Trains the TP-MLP block (python/compile/model.py, Pallas-backed GEMMs,
//! AOT-lowered to HLO) for several hundred steps on synthetic
//! teacher-generated data, TP=4, with the Rust coordinator driving:
//!
//!   per step:  workers: mlp_fwd partial (PJRT)       [sliced GEMM]
//!              leader:  ring-all-reduce of partials  [the serialized AR]
//!              workers: loss_grad (replicated), mlp_bwd (PJRT)
//!              leader:  SGD update of each device's weight slices
//!
//! The loss curve is logged (results/train_loss.csv) — proving all three
//! layers compose: L1 Pallas kernel -> L2 JAX graphs -> L3 Rust
//! runtime/collectives. Alongside, the timing simulator reports what each
//! training iteration of the same pattern costs at paper scale under
//! Sequential vs T3-MCA (the paper's headline: up to 12% training
//! speedup).
//!
//! Run: `make artifacts && cargo run --release --example train_e2e`

use t3::config::SystemConfig;
use t3::coordinator::Coordinator;
use t3::exec::{end_to_end, Scenario};
use t3::models::breakdown::Phase;
use t3::models::by_name;
use t3::runtime::{Runtime, TensorF32};
use t3::sim::rng::Rng;

// Mirror of python/compile/model.py constants.
const TOKENS: usize = 256;
const HIDDEN: usize = 512;
const FFN_SLICE: usize = 512; // FFN (2048) / TP (4)
const TP: usize = 4;

const STEPS: usize = 300;
const LR: f32 = 0.1;

fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    // Box-Muller-ish via sum of uniforms (Irwin-Hall, good enough here).
    (0..n)
        .map(|_| {
            let s: f32 = (0..6).map(|_| rng.f32_range(-1.0, 1.0)).sum();
            s / 6.0f32.sqrt() * scale * 2.44949
        })
        .collect()
}

fn axpy(w: &mut [f32], g: &[f32], lr: f32) {
    for (w, g) in w.iter_mut().zip(g) {
        *w -= lr * g;
    }
}

fn main() -> t3::error::Result<()> {
    println!("== train_e2e: TP={TP} MLP through Pallas->HLO->PJRT + Rust ring collectives ==");
    if !Runtime::pjrt_enabled() {
        eprintln!("built without the `pjrt` feature — rebuild with `--features pjrt`");
        std::process::exit(2);
    }
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut coord = Coordinator::new(TP, dir)?;
    let mut rng = Rng::new(0xDEED);

    // Data: fixed input batch + teacher targets (a random 2-layer tanh
    // teacher, like model.teacher_targets but host-side).
    let x = randn(&mut rng, TOKENS * HIDDEN, 1.0);
    let wt1 = randn(&mut rng, HIDDEN * HIDDEN, 0.05);
    let wt2 = randn(&mut rng, HIDDEN * HIDDEN, 0.05);
    let mut target = vec![0.0f32; TOKENS * HIDDEN];
    {
        let mut h = vec![0.0f32; TOKENS * HIDDEN];
        for r in 0..TOKENS {
            for c in 0..HIDDEN {
                let mut acc = 0.0f32;
                for k in 0..HIDDEN {
                    acc += x[r * HIDDEN + k] * wt1[k * HIDDEN + c];
                }
                h[r * HIDDEN + c] = acc.tanh();
            }
        }
        for r in 0..TOKENS {
            for c in 0..HIDDEN {
                let mut acc = 0.0f32;
                for k in 0..HIDDEN {
                    acc += h[r * HIDDEN + k] * wt2[k * HIDDEN + c];
                }
                target[r * HIDDEN + c] = acc;
            }
        }
    }

    // Per-device weight slices.
    let mut w1s: Vec<Vec<f32>> = (0..TP)
        .map(|_| randn(&mut rng, HIDDEN * FFN_SLICE, 0.05))
        .collect();
    let mut w2s: Vec<Vec<f32>> = (0..TP)
        .map(|_| randn(&mut rng, FFN_SLICE * HIDDEN, 0.05))
        .collect();

    let t0 = std::time::Instant::now();
    let mut losses: Vec<(usize, f32)> = Vec::new();
    for step in 0..STEPS {
        // forward partials on every device
        let inputs: Vec<Vec<TensorF32>> = (0..TP)
            .map(|d| {
                vec![
                    TensorF32::new(x.clone(), &[TOKENS, HIDDEN]),
                    TensorF32::new(w1s[d].clone(), &[HIDDEN, FFN_SLICE]),
                    TensorF32::new(w2s[d].clone(), &[FFN_SLICE, HIDDEN]),
                ]
            })
            .collect();
        let fwd = coord.exec_all("mlp_fwd", inputs)?;
        let (partials, h_pres): (Vec<Vec<f32>>, Vec<Vec<f32>>) = fwd
            .into_iter()
            .map(|mut o| {
                let h = o.swap_remove(1);
                let y = o.swap_remove(0);
                (y, h)
            })
            .unzip();
        // the serialized AR the paper overlaps
        let y = coord.all_reduce(partials);
        // replicated loss grad (device 0 suffices; all devices identical)
        let lg = coord.exec_all(
            "loss_grad",
            (0..TP)
                .map(|_| {
                    vec![
                        TensorF32::new(y.clone(), &[TOKENS, HIDDEN]),
                        TensorF32::new(target.clone(), &[TOKENS, HIDDEN]),
                    ]
                })
                .collect(),
        )?;
        let loss = lg[0][0][0];
        let dy = lg[0][1].clone();
        // per-device backward
        let bwd_inputs: Vec<Vec<TensorF32>> = (0..TP)
            .map(|d| {
                vec![
                    TensorF32::new(x.clone(), &[TOKENS, HIDDEN]),
                    TensorF32::new(h_pres[d].clone(), &[TOKENS, FFN_SLICE]),
                    TensorF32::new(w2s[d].clone(), &[FFN_SLICE, HIDDEN]),
                    TensorF32::new(dy.clone(), &[TOKENS, HIDDEN]),
                ]
            })
            .collect();
        let bwd = coord.exec_all("mlp_bwd", bwd_inputs)?;
        for (d, mut grads) in bwd.into_iter().enumerate() {
            let dw2 = grads.swap_remove(1);
            let dw1 = grads.swap_remove(0);
            axpy(&mut w1s[d], &dw1, LR);
            axpy(&mut w2s[d], &dw2, LR);
        }
        if step % 20 == 0 || step + 1 == STEPS {
            println!("  step {step:4}  loss {loss:.6}");
        }
        losses.push((step, loss));
    }
    let wall = t0.elapsed();
    let first = losses.first().unwrap().1;
    let last = losses.last().unwrap().1;
    println!(
        "trained {STEPS} steps in {:.1}s ({:.1} ms/step): loss {first:.4} -> {last:.4}",
        wall.as_secs_f64(),
        wall.as_secs_f64() * 1e3 / STEPS as f64
    );
    assert!(last < first * 0.5, "loss did not converge");
    std::fs::create_dir_all("results")?;
    let csv: String = "step,loss\n".to_string()
        + &losses
            .iter()
            .map(|(s, l)| format!("{s},{l}"))
            .collect::<Vec<_>>()
            .join("\n");
    std::fs::write("results/train_loss.csv", csv)?;
    println!("loss curve -> results/train_loss.csv");

    // ---- what this iteration pattern costs at paper scale ----
    println!("\nsimulated training iteration at paper scale (Mega-GPT-2, TP=16):");
    let sys = SystemConfig::table1();
    let m = by_name("Mega-GPT-2").unwrap();
    let e = end_to_end(
        &sys,
        &m,
        16,
        Phase::Training,
        &[Scenario::Sequential, Scenario::T3, Scenario::T3Mca],
    );
    for sc in [Scenario::Sequential, Scenario::T3, Scenario::T3Mca] {
        println!(
            "  {:12} {:8.2} ms/iter  ({:.3}x)",
            sc.name(),
            e.total(sc).as_ms_f64(),
            e.speedup(sc)
        );
    }
    println!("\ntrain_e2e OK");
    Ok(())
}
