//! Quickstart: the whole stack in one file.
//!
//! 1. Numeric path — spawn a 4-device TP coordinator, execute the
//!    AOT-compiled sliced-GEMM artifact (Pallas kernel -> HLO -> PJRT) on
//!    every device, ring-all-reduce the partials with the functional
//!    collective, and check the result against a CPU oracle.
//! 2. Timing path — simulate the same serialized "GEMM -> AR" pattern at
//!    paper scale (T-NLG FC-2, TP=8) under Sequential vs T3 vs T3-MCA and
//!    print the speedups (paper Figure 16).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use t3::config::SystemConfig;
use t3::coordinator::Coordinator;
use t3::exec::{run_sublayer, sublayer_speedup, Scenario};
use t3::models::{by_name, SubLayer};
use t3::runtime::{Runtime, TensorF32};
use t3::sim::rng::Rng;

fn main() -> t3::error::Result<()> {
    println!("== T3 quickstart ==\n");

    // ---------------- numeric path ----------------
    let dir = Runtime::default_dir();
    if Runtime::pjrt_enabled() && Runtime::artifacts_available(&dir) {
        let tp = 4usize;
        let (m, k, n) = (256usize, 128usize, 512usize);
        let mut coord = Coordinator::new(tp, dir)?;
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32_range(-0.5, 0.5)).collect();
        let xs: Vec<Vec<f32>> = (0..tp)
            .map(|_| (0..m * k).map(|_| rng.f32_range(-0.5, 0.5)).collect())
            .collect();
        // Every worker runs its K-slice partial GEMM through PJRT...
        let inputs: Vec<Vec<TensorF32>> = xs
            .iter()
            .map(|x| {
                vec![
                    TensorF32::new(x.clone(), &[m, k]),
                    TensorF32::new(w.clone(), &[k, n]),
                ]
            })
            .collect();
        let outs = coord.exec_all("sliced_gemm", inputs)?;
        // ...and the leader all-reduces the partials with the functional
        // ring (the dataflow T3 performs in hardware).
        let partials: Vec<Vec<f32>> = outs.into_iter().map(|mut o| o.swap_remove(0)).collect();
        let reduced = coord.all_reduce(partials);
        // Oracle.
        let mut want = vec![0.0f64; m * n];
        for x in &xs {
            for r in 0..m {
                for c in 0..n {
                    let mut acc = 0.0;
                    for kk in 0..k {
                        acc += x[r * k + kk] as f64 * w[kk * n + c] as f64;
                    }
                    want[r * n + c] += acc;
                }
            }
        }
        let max_err = reduced
            .iter()
            .zip(&want)
            .map(|(a, b)| (*a as f64 - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "numeric: {tp}-device sliced GEMM (Pallas->HLO->PJRT) + ring-AR vs oracle: max err {max_err:.2e}"
        );
        assert!(max_err < 1e-3);
    } else {
        println!(
            "numeric: skipped (build with `--features pjrt` and run `make artifacts` \
             to enable the PJRT path)"
        );
    }

    // ---------------- timing path ----------------
    let sys = SystemConfig::table1();
    let model = by_name("T-NLG").unwrap();
    let tp = 8;
    println!("\ntiming: T-NLG FC-2(fwd), TP={tp}, Table-1 system");
    let seq = run_sublayer(&sys, &model, tp, SubLayer::Fc2Fwd, Scenario::Sequential);
    println!(
        "  Sequential: GEMM {:.3}ms + RS {:.3}ms + AG {:.3}ms = {:.3}ms",
        seq.gemm.as_ms_f64(),
        seq.rs.as_ms_f64(),
        seq.ag.as_ms_f64(),
        seq.total.as_ms_f64()
    );
    for sc in [Scenario::T3, Scenario::T3Mca, Scenario::IdealOverlap] {
        let r = run_sublayer(&sys, &model, tp, SubLayer::Fc2Fwd, sc);
        println!(
            "  {:22} {:.3}ms  ({:.2}x)",
            sc.name(),
            r.total.as_ms_f64(),
            sublayer_speedup(&seq, &r)
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
