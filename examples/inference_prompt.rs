//! Inference prompt-phase serving through the coordinator + batcher.
//!
//! A synthetic arrival trace of prompt requests is dynamically batched
//! (token-budget + max-wait policy); every batch runs the TP forward
//! block through PJRT on all workers with the serialized all-reduce in
//! between, measuring real wall-clock latency/throughput. The timing
//! simulator then reports what each batch's sliced sub-layers would cost
//! at paper scale under Sequential vs T3-MCA (paper: prompt phase up to
//! 15% faster).
//!
//! Run: `make artifacts && cargo run --release --example inference_prompt`

use t3::config::SystemConfig;
use t3::coordinator::batcher::{BatchPolicy, Batcher, Request};
use t3::coordinator::Coordinator;
use t3::exec::{end_to_end, Scenario};
use t3::models::breakdown::Phase;
use t3::models::by_name;
use t3::runtime::{Runtime, TensorF32};
use t3::sim::rng::Rng;
use t3::sim::time::SimTime;

const TOKENS: usize = 256;
const HIDDEN: usize = 512;
const FFN_SLICE: usize = 512;
const TP: usize = 4;
const NUM_REQUESTS: u64 = 64;

fn main() -> t3::error::Result<()> {
    println!("== inference_prompt: batched TP prompt serving ==");
    if !Runtime::pjrt_enabled() {
        eprintln!("built without the `pjrt` feature — rebuild with `--features pjrt`");
        std::process::exit(2);
    }
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut coord = Coordinator::new(TP, dir)?;
    let mut rng = Rng::new(3);

    // Synthetic arrival trace: bursty Poisson-ish arrivals, prompt sizes
    // 32-256 tokens.
    let mut batcher = Batcher::new(BatchPolicy {
        max_tokens: TOKENS as u64,
        max_requests: 8,
        max_wait: SimTime::us(200),
    });
    let mut t = SimTime::ZERO;
    let mut arrivals = Vec::new();
    for id in 0..NUM_REQUESTS {
        t += SimTime::us(rng.range(10, 120));
        arrivals.push(Request {
            id,
            tokens: rng.range(32, 257),
            arrival: t,
        });
    }

    // Fixed weights; per-batch input is random (batch identity is what we
    // measure, not the numerics here — those are covered by train_e2e).
    let w1: Vec<f32> = (0..HIDDEN * FFN_SLICE).map(|_| rng.f32_range(-0.05, 0.05)).collect();
    let w2: Vec<f32> = (0..FFN_SLICE * HIDDEN).map(|_| rng.f32_range(-0.05, 0.05)).collect();

    let mut batches = 0u64;
    let mut served = 0u64;
    let mut total_tokens = 0u64;
    let mut queue_delays = Vec::new();
    let wall0 = std::time::Instant::now();
    let mut exec_wall = std::time::Duration::ZERO;

    let mut i = 0;
    while i < arrivals.len() || batcher.pending() > 0 {
        // Feed arrivals up to the batcher's next decision point.
        if i < arrivals.len() {
            let now = arrivals[i].arrival;
            batcher.push(arrivals[i].clone());
            i += 1;
            // try to form a batch at this arrival time
            while let Some(batch) = batcher.next_batch(now) {
                serve(&mut coord, &w1, &w2, &batch, &mut exec_wall)?;
                batches += 1;
                served += batch.requests.len() as u64;
                total_tokens += batch.tokens();
                for r in &batch.requests {
                    queue_delays.push(now.saturating_sub(r.arrival).as_us_f64());
                }
            }
        } else {
            let Some(batch) = batcher.flush() else { break };
            serve(&mut coord, &w1, &w2, &batch, &mut exec_wall)?;
            batches += 1;
            served += batch.requests.len() as u64;
            total_tokens += batch.tokens();
        }
    }
    let wall = wall0.elapsed();
    assert_eq!(served, NUM_REQUESTS);
    let mean_delay = queue_delays.iter().sum::<f64>() / queue_delays.len().max(1) as f64;
    println!(
        "served {served} requests in {batches} batches | {total_tokens} tokens | \
         wall {:.2}s | exec {:.2}s | {:.0} tok/s | mean queue delay {:.0}us (sim)",
        wall.as_secs_f64(),
        exec_wall.as_secs_f64(),
        total_tokens as f64 / exec_wall.as_secs_f64(),
        mean_delay
    );

    // ---- paper-scale per-iteration prompt cost ----
    println!("\nsimulated prompt iteration at paper scale (T-NLG, TP=8):");
    let sys = SystemConfig::table1();
    let m = by_name("T-NLG").unwrap();
    let e = end_to_end(
        &sys,
        &m,
        8,
        Phase::Prompt,
        &[Scenario::Sequential, Scenario::T3, Scenario::T3Mca],
    );
    for sc in [Scenario::Sequential, Scenario::T3, Scenario::T3Mca] {
        println!(
            "  {:12} {:8.2} ms  ({:.3}x)",
            sc.name(),
            e.total(sc).as_ms_f64(),
            e.speedup(sc)
        );
    }
    println!("\ninference_prompt OK");
    Ok(())
}

fn serve(
    coord: &mut Coordinator,
    w1: &[f32],
    w2: &[f32],
    batch: &t3::coordinator::batcher::Batch,
    exec_wall: &mut std::time::Duration,
) -> t3::error::Result<()> {
    // Pack the batch into the fixed [TOKENS, HIDDEN] activation (padding
    // semantics: unused rows are zero).
    let mut x = vec![0.0f32; TOKENS * HIDDEN];
    let mut row = 0usize;
    let mut h = 0x9E3779B97F4A7C15u64;
    for r in &batch.requests {
        for _ in 0..r.tokens.min((TOKENS - row) as u64) {
            for c in 0..HIDDEN {
                // cheap deterministic fill
                h ^= h << 13;
                h ^= h >> 7;
                h ^= h << 17;
                x[row * HIDDEN + c] = ((h >> 40) as f32 / (1u64 << 24) as f32) - 0.5;
            }
            row += 1;
        }
    }
    let inputs: Vec<Vec<TensorF32>> = (0..TP)
        .map(|_| {
            vec![
                TensorF32::new(x.clone(), &[TOKENS, HIDDEN]),
                TensorF32::new(w1.to_vec(), &[HIDDEN, FFN_SLICE]),
                TensorF32::new(w2.to_vec(), &[FFN_SLICE, HIDDEN]),
            ]
        })
        .collect();
    let t0 = std::time::Instant::now();
    let outs = coord.exec_all("mlp_fwd", inputs)?;
    let partials: Vec<Vec<f32>> = outs.into_iter().map(|mut o| o.swap_remove(0)).collect();
    let y = coord.all_reduce(partials);
    *exec_wall += t0.elapsed();
    t3::ensure!(y.iter().all(|v| v.is_finite()), "non-finite activation");
    Ok(())
}
